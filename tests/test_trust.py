"""repro.trust: reputation-weighted screening + the equivocation echo protocol.

The trust contract (ISSUE 7 acceptance):
* (a) trust OFF is structurally absent (``state.trust is None``, no trust
  metric streams), and trust ON is BIT-INERT until it acts — with a plain
  (unweighted) rule and ``warmup`` beyond the horizon the trajectory is
  bitwise the trust-free one, across rule x attack x codec, sync + net
  paths, dense + sparse layouts;
* (b) the echo protocol catches equivocators: per-receiver lies surface as
  quorum-confirmed digest mismatches, the lying sender's in-edges are
  evicted, and honest edges are NEVER evicted;
* (c) slander is structurally impossible: <= b forged accusations can never
  meet the b + 1 disagreeing-witness quorum, so a slandered honest sender
  keeps its edges;
* (d) the dense and sparse layouts agree bitwise with trust compiled in;
plus unit coverage of the evidence quorum, the reputation fold, the
weighted rules, and the relaxed degree requirement the breakdown study
spends (``rep_* : b + 1`` vs ``2b + 1``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BridgeConfig, BridgeTrainer, complete_graph, erdos_renyi, replicate, screening
from repro.core.bridge import stack_batches
from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer, ChannelConfig
from repro.net import mailbox as mb
from repro.sim import ExperimentGrid, GridEngine
from repro.trust import TrustSpec, echo, edge_weights, init_state, summarize, update

M, D, T = 12, 5, 12


def quad_grad_fn(params, batch):
    w, c = params["w"], batch
    loss = 0.5 * jnp.sum((w - c) ** 2)
    return loss, {"w": w - c}


@pytest.fixture(scope="module")
def topo():
    return erdos_renyi(M, 0.8, 2, seed=1)


@pytest.fixture(scope="module")
def targets():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(M, D)), jnp.float32)


def init_fn(seed):
    return replicate({"w": jnp.zeros(D)}, M, perturb=0.1, key=jax.random.PRNGKey(seed))


@pytest.fixture(scope="module")
def batches(targets):
    return stack_batches(lambda i: targets, T)


# trust that runs every tick but cannot act: plain rules ignore the weights
# and warmup past the horizon keeps the eviction mask all-False
INERT = TrustSpec(warmup=T + 1)


def _sync_run(topo, targets, *, rule="trimmed_mean", attack="alie",
              codec="identity", sparse=False, trust=None, ticks=T, b=2):
    cfg = BridgeConfig(topology=topo, rule=rule, num_byzantine=b, attack=attack,
                       codec=codec, sparse=sparse, trust=trust, lam=1.0, t0=10.0)
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    losses = []
    for _ in range(ticks):
        st, m = tr.step(st, targets)
        losses.append(m["loss"])
    return tr, st, np.asarray(jnp.stack(losses))


def _net_run(topo, batches, *, sparse, trust=None):
    cfg = AsyncBridgeConfig(
        topology=topo, rule="trimmed_mean", num_byzantine=2, attack="alie",
        channel=ChannelConfig(drop_prob=0.1), staleness_bound=2,
        lam=1.0, t0=10.0, sparse=sparse, trust=trust)
    tr = AsyncBridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    st, metrics = tr.run_scan(st, batches)
    return tr, st, metrics


# ---------------------------------------------------------------------------
# (a) off = absent; on-but-inert = bitwise the trust-free trajectory
# ---------------------------------------------------------------------------


def test_trust_off_is_structurally_absent(topo, targets):
    cfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                       attack="alie", lam=1.0, t0=10.0)
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    assert st.trust is None
    st, metrics = tr.step(st, targets)
    assert st.trust is None
    assert "trust_evicted_frac" not in metrics


@pytest.mark.parametrize("rule,attack,codec,sparse", [
    ("trimmed_mean", "alie", "identity", False),
    ("trimmed_mean", "sign_flip", "int8", False),
    ("median", "alie", "identity", True),
    ("krum", "random", "identity", False),
])
def test_sync_trust_bit_inert(topo, targets, rule, attack, codec, sparse):
    """Echo + reputation compiled into the step change NOTHING about the
    trajectory until an eviction latches or a weighted rule consumes the
    weights — with a plain rule and warmup > T, bitwise equality."""
    _, st_off, ls_off = _sync_run(topo, targets, rule=rule, attack=attack,
                                  codec=codec, sparse=sparse, trust=None)
    _, st_on, ls_on = _sync_run(topo, targets, rule=rule, attack=attack,
                                codec=codec, sparse=sparse, trust=INERT)
    np.testing.assert_array_equal(np.asarray(st_off.params["w"]),
                                  np.asarray(st_on.params["w"]))
    np.testing.assert_array_equal(ls_off, ls_on)
    assert st_off.trust is None
    assert not bool(jnp.any(st_on.trust.evicted))


@pytest.mark.parametrize("sparse", [False, True])
def test_net_trust_bit_inert(topo, batches, sparse):
    """The network-runtime path (drops, staleness, real send generations)."""
    _, st_off, ms_off = _net_run(topo, batches, sparse=sparse, trust=None)
    _, st_on, ms_on = _net_run(topo, batches, sparse=sparse, trust=INERT)
    np.testing.assert_array_equal(np.asarray(st_off.params["w"]),
                                  np.asarray(st_on.params["w"]))
    np.testing.assert_array_equal(np.asarray(ms_off["loss"]),
                                  np.asarray(ms_on["loss"]))
    assert "trust_evicted_frac" in ms_on


def test_grid_trust_bit_inert(topo, batches):
    grid = ExperimentGrid(topo, ("trimmed_mean", "median"), ("alie",), (2,),
                          (0, 1), lam=1.0, t0=10.0)
    eng_off = GridEngine(grid, quad_grad_fn)
    fin_off, ms_off = eng_off.run(eng_off.init(init_fn), batches)
    eng_on = GridEngine(grid, quad_grad_fn, trust=INERT)
    fin_on, ms_on = eng_on.run(eng_on.init(init_fn), batches)
    np.testing.assert_array_equal(np.asarray(fin_off.params["w"]),
                                  np.asarray(fin_on.params["w"]))
    np.testing.assert_array_equal(np.asarray(ms_off["loss"]),
                                  np.asarray(ms_on["loss"]))
    assert fin_on.trust.suspicion.shape[0] == eng_on.num_cells


def test_trust_spec_validation():
    with pytest.raises(ValueError, match="TrustSpec"):
        TrustSpec(decay=1.5)
    with pytest.raises(ValueError, match="TrustSpec"):
        TrustSpec(evict_threshold=0.0)
    with pytest.raises(ValueError, match="TrustSpec"):
        TrustSpec(digest_dim=0)
    with pytest.raises(ValueError, match="TrustSpec"):
        TrustSpec(warmup=-1)


def test_trust_spec_is_zero_leaf_pytree():
    spec = TrustSpec()
    assert jax.tree_util.tree_leaves(spec) == []
    assert jax.tree_util.tree_map(lambda x: x, spec) == spec


# ---------------------------------------------------------------------------
# (b) + (c) end-to-end: equivocators evicted, slander impossible
# ---------------------------------------------------------------------------


def _detection_grid(adversaries, *, m=10, b=1, ticks=8, warmup=2):
    # complete graph: one-hop digest gossip needs triangles — every pair of
    # witnesses of a sender must also be adjacent to the receiver
    topo = complete_graph(m, b)
    rng = np.random.default_rng(3)
    targets = jnp.asarray(rng.normal(size=(m, D)), jnp.float32)

    def ifn(seed):
        return replicate({"w": jnp.zeros(D)}, m, perturb=0.1,
                         key=jax.random.PRNGKey(seed))

    spec = TrustSpec(warmup=warmup)
    grid = ExperimentGrid(topo, ("rep_trimmed_mean",), ("none",), (b,), (0,),
                          scenarios=("ideal",), adversaries=adversaries,
                          lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn, num_ticks=ticks, trust=spec)
    final, _ = engine.run(engine.init(ifn),
                          stack_batches(lambda i: targets, ticks))
    records = {}
    for i, cell in enumerate(engine.cells):
        trust_i = jax.tree_util.tree_map(lambda leaf: leaf[i], final.trust)
        records[cell.adversary] = summarize(spec, trust_i,
                                            byz_mask=engine.byz_masks[i],
                                            senders=engine.sender_grid())
    return records, final, engine


def test_equivocators_evicted_honest_edges_kept():
    records, _, _ = _detection_grid(("equivocate",))
    rec = records["equivocate"]
    assert rec["byz_eviction_rate"] >= 0.8
    assert rec["honest_evicted"] == 0
    assert rec["auc_byzantine_edges"] >= 0.9


def test_slander_cannot_frame_honest_senders():
    # b = 2 slanderers forge every digest they gossip; the b + 1 = 3 quorum
    # means no honest receiver ever sees enough disagreeing witnesses
    records, _, _ = _detection_grid(("slander",), b=2)
    rec = records["slander"]
    assert rec["honest_evicted"] == 0
    assert rec["byz_evicted"] == 0  # slander alone never convicts anyone


def test_trust_dense_sparse_grids_agree_bitwise():
    """(d) the echo protocol is computed in dense [M, M] space on BOTH
    layouts, so trust-on trajectories agree across them bitwise."""
    m, b, ticks = 10, 1, 8
    topo = complete_graph(m, b)
    rng = np.random.default_rng(3)
    targets = jnp.asarray(rng.normal(size=(m, D)), jnp.float32)

    def ifn(seed):
        return replicate({"w": jnp.zeros(D)}, m, perturb=0.1,
                         key=jax.random.PRNGKey(seed))

    spec = TrustSpec(warmup=2)
    grid = ExperimentGrid(topo, ("rep_trimmed_mean",), ("none",), (b,), (0,),
                          scenarios=("ideal",), adversaries=("equivocate",),
                          lam=1.0, t0=10.0)
    outs = []
    for sparse in (False, True):
        eng = GridEngine(grid, quad_grad_fn, num_ticks=ticks, trust=spec,
                         sparse=sparse)
        fin, _ = eng.run(eng.init(ifn), stack_batches(lambda i: targets, ticks))
        outs.append(np.asarray(fin.params["w"]))
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# echo protocol units: quorum math, generation gating, layouts
# ---------------------------------------------------------------------------


def _echo_setup(m=6, q=3, b=1):
    digests = jnp.zeros((m, m, q), jnp.float32)  # [holder, sender, q]
    gens = jnp.zeros((m, m), jnp.int32)
    valid = jnp.ones((m, m), bool)
    gossip = jnp.asarray(~np.eye(m, dtype=bool))
    return digests, gens, valid, gossip


def test_equivocation_evidence_quorum():
    digests, gens, valid, gossip = _echo_setup()
    # sender 0 lied to receiver 1: all 5 other holders (including sender 0's
    # own row) disagree with receiver 1's digest
    digests = digests.at[1, 0].set(5.0)
    ev, mism = echo.equivocation_evidence(digests, gens, valid, gossip, 1,
                                          tol=1e-3)
    assert float(mism[1, 0]) == 5.0
    assert bool(ev[1, 0])  # 5 disagreeing witnesses >= b + 1 = 2
    # each majority-payload holder sees exactly ONE disagreeing witness
    # (receiver 1) — below quorum, so the lie only convicts at receiver 1
    assert not bool(jnp.any(ev.at[1, 0].set(False)))


def test_equivocation_evidence_below_quorum():
    digests, gens, valid, gossip = _echo_setup()
    digests = digests.at[1, 0].set(5.0)
    # 5 disagreeing witnesses: the quorum b + 1 is met up to b = 4 ...
    ev4, _ = echo.equivocation_evidence(digests, gens, valid, gossip, 4,
                                        tol=1e-3)
    assert bool(ev4[1, 0])
    # ... and structurally unreachable at b = 5 (only 5 witnesses exist)
    ev5, _ = echo.equivocation_evidence(digests, gens, valid, gossip, 5,
                                        tol=1e-3)
    assert not bool(jnp.any(ev5))


def test_equivocation_evidence_generation_gated():
    """Stale or never-delivered copies are excluded: only witnesses holding
    the SAME send generation may testify (drops/latency != equivocation)."""
    digests, gens, valid, gossip = _echo_setup()
    digests = digests.at[1, 0].set(5.0)
    gens = gens.at[2, 0].set(mb.NEVER).at[3, 0].set(7)  # two witnesses out
    ev, mism = echo.equivocation_evidence(digests, gens, valid, gossip, 2,
                                          tol=1e-3)
    assert float(mism[1, 0]) == 3.0  # holders 0, 4, 5 — 2 and 3 excluded
    assert bool(ev[1, 0])  # 3 >= b + 1 = 3, exactly at quorum
    ev3, _ = echo.equivocation_evidence(digests, gens, valid, gossip, 3,
                                        tol=1e-3)
    assert not bool(jnp.any(ev3))  # quorum 4 unreachable once gens gate


def test_scatter_dense_roundtrip():
    from repro.core.neighbors import NeighborTable

    adj = np.asarray(complete_graph(6, 1).adjacency)
    nbr = NeighborTable.from_adjacency(jnp.asarray(adj))
    rng = np.random.default_rng(0)
    dense = jnp.asarray(rng.normal(size=(6, 6)), jnp.float32)
    gathered = nbr.gather_edges(dense, 0.0)
    back = echo.scatter_dense(nbr, gathered, 0.0)
    np.testing.assert_array_equal(np.asarray(back * adj),
                                  np.asarray(dense * adj))


# ---------------------------------------------------------------------------
# reputation fold units
# ---------------------------------------------------------------------------


def test_reputation_update_and_eviction_latch():
    spec = TrustSpec(decay=0.5, trim_weight=1.0, echo_weight=4.0,
                     evict_threshold=0.5, warmup=2)
    st = init_state(spec, 2, 2)
    live = jnp.ones((2, 2), bool)
    hot = jnp.zeros((2, 2), bool).at[0, 1].set(True)
    for t in range(4):
        st = update(spec, st, t=jnp.asarray(t), trim_frac=jnp.zeros((2, 2)),
                    live=live, echo_evidence=hot.astype(jnp.float32))
    # echo evidence saturates suspicion on the hot edge only
    assert float(st.suspicion[0, 1]) > 0.9
    assert float(jnp.max(jnp.where(hot, 0.0, st.suspicion))) == 0.0
    assert bool(st.evicted[0, 1])  # latched once t >= warmup
    assert int(jnp.sum(st.evicted)) == 1
    w = edge_weights(spec, st)
    assert float(w[0, 1]) == 0.0
    assert float(jnp.min(jnp.where(hot, 1.0, w))) == 1.0
    # the latch never releases, even if the evidence stops
    st = update(spec, st, t=jnp.asarray(9), trim_frac=jnp.zeros((2, 2)),
                live=live, echo_evidence=None)
    assert bool(st.evicted[0, 1])


def test_reputation_centered_trim_and_frozen_dead_edges():
    spec = TrustSpec(decay=0.5, warmup=0)
    st = init_state(spec, 1, 2)
    # edge 1 trimmed far above the live average (0.5) -> accrues suspicion;
    # edge 0 sits below the average -> relu clamps it to exactly zero
    st = update(spec, st, t=jnp.asarray(0), trim_frac=jnp.asarray([[0.0, 1.0]]),
                live=jnp.ones((1, 2), bool))
    assert float(st.suspicion[0, 0]) == 0.0
    before = float(st.suspicion[0, 1])
    assert before == pytest.approx(0.25)  # 0.5 * relu(1 - 0.5)
    st = update(spec, st, t=jnp.asarray(1), trim_frac=jnp.zeros((1, 2)),
                live=jnp.asarray([[True, False]]))
    assert float(st.suspicion[0, 1]) == before  # no decay while unreachable


def test_summarize_splits_honest_and_byzantine():
    spec = TrustSpec(warmup=0)
    st = init_state(spec, 3, 3)
    st = st._replace(
        evicted=jnp.zeros((3, 3), bool).at[0, 2].set(True),
        suspicion=jnp.zeros((3, 3)).at[0, 2].set(0.9).at[1, 2].set(0.8))
    senders = np.tile(np.arange(3), (3, 1))  # slot j holds sender j
    byz = np.asarray([False, False, True])
    rec = summarize(spec, st, byz_mask=byz, senders=senders)
    assert rec["byz_evicted"] == 1 and rec["honest_evicted"] == 0
    assert rec["byz_eviction_rate"] == pytest.approx(0.5)  # 1 of 2 byz edges
    # both Byzantine in-edges outrank every honest edge's 0 suspicion
    assert rec["auc_byzantine_edges"] == 1.0


# ---------------------------------------------------------------------------
# weighted rules + the relaxed degree table
# ---------------------------------------------------------------------------


def _ref_rep_trimmed_mean(v, w, sv, b):
    """Independent oracle: per coordinate, keep values inside the [b-th,
    (n-1-b)-th] order-statistic window, then reputation-weighted average
    with self at weight 1."""
    out = []
    for c in range(v.shape[1]):
        col = v[:, c]
        order = np.sort(col)
        lo, hi = order[b], order[-b - 1]
        kept = (col >= lo) & (col <= hi)
        out.append((np.sum(w * kept * col) + sv[c]) / (np.sum(w * kept) + 1.0))
    return np.asarray(out)


def test_rep_trimmed_mean_matches_oracle_with_zero_weight():
    rng = np.random.default_rng(7)
    n, d, b = 9, 6, 2
    v = rng.normal(size=(n, d)).astype(np.float32)
    sv = rng.normal(size=(d,)).astype(np.float32)
    # a zero-weight (evicted) edge and a down-weighted one: the trim window
    # is computed mask-wise, the weights act on the kept average only
    w = np.ones((n,), np.float32)
    w[3], w[5] = 0.0, 0.25
    y = screening.rep_trimmed_mean(jnp.asarray(v), jnp.ones((n,), bool),
                                   jnp.asarray(sv), b, weights=jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(y), _ref_rep_trimmed_mean(v, w, sv, b),
                               rtol=1e-5)


def test_rep_median_weight_zero_equals_masked_out():
    rng = np.random.default_rng(7)
    n, d = 9, 6
    v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    sv = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    w = jnp.ones((n,)).at[3].set(0.0)
    y_w = screening.rep_median(v, jnp.ones((n,), bool), sv, weights=w)
    y_m = screening.rep_median(v, jnp.ones((n,), bool).at[3].set(False), sv,
                               weights=w)
    np.testing.assert_array_equal(np.asarray(y_w), np.asarray(y_m))
    # and an overwhelming-reputation edge pins the weighted median
    y_pin = screening.rep_median(v, jnp.ones((n,), bool), sv,
                                 weights=jnp.ones((n,)).at[3].set(100.0))
    np.testing.assert_array_equal(np.asarray(y_pin), np.asarray(v[3]))


def test_rep_trimmed_mean_uniform_weights_is_tie_inclusive_trim():
    rng = np.random.default_rng(11)
    n, d, b = 7, 4, 1
    v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    mask = jnp.ones((n,), bool)
    sv = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    y = screening.rep_trimmed_mean(v, mask, sv, b)
    # tie-free draws: the kept window is exactly the sorted interior, so the
    # uniform-weight answer is the classic trimmed mean (self included)
    vs = np.sort(np.asarray(v), axis=0)[b:-b]
    expect = (vs.sum(0) + np.asarray(sv)) / (vs.shape[0] + 1)
    np.testing.assert_allclose(np.asarray(y), expect, rtol=1e-5)


def test_rep_rules_relax_min_neighbors():
    assert screening.min_neighbors("trimmed_mean", 3) == 7   # 2b + 1
    assert screening.min_neighbors("rep_trimmed_mean", 3) == 4  # b + 1
    assert screening.min_neighbors("rep_median", 3) == 1
