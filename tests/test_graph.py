"""Topology generation and Assumption 4 checking."""
import numpy as np
import pytest

from repro.core import (
    Topology,
    check_assumption4,
    complete_graph,
    erdos_renyi,
    metropolis_weights,
    ring_of_cliques,
)


def test_er_satisfies_paper_recipe():
    topo = erdos_renyi(20, 0.5, 2, seed=0)
    assert topo.min_in_degree > 4  # > 2b
    assert check_assumption4(topo, num_samples=10, seed=1)


def test_complete_graph_assumption4():
    topo = complete_graph(10, 2)
    assert check_assumption4(topo, num_samples=10)


def test_ring_of_cliques_fails_assumption4():
    # bottleneck single links: removing b incoming edges disconnects
    topo = ring_of_cliques(4, 4, num_byzantine=2)
    assert not check_assumption4(topo, num_samples=40, seed=0)


def test_rule_neighborhood_requirements():
    topo = complete_graph(6, 2)  # degree 5
    topo.validate_for_rule("trimmed_mean")  # needs 5 ✓
    with pytest.raises(ValueError):
        topo.validate_for_rule("bulyan")  # needs max(8, 8)+1 = 9
    with pytest.raises(ValueError):
        Topology(adjacency=np.eye(3, dtype=bool), num_byzantine=0)  # self loops


def test_metropolis_weights_doubly_stochastic():
    topo = erdos_renyi(12, 0.6, 1, seed=2)
    w = metropolis_weights(topo)
    np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-9)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-9)
    assert (w >= 0).all()


def test_no_self_loops_and_symmetry():
    topo = erdos_renyi(10, 0.7, 1, seed=5)
    assert not topo.adjacency.diagonal().any()
    assert (topo.adjacency == topo.adjacency.T).all()
