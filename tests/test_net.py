"""repro.net: mailboxes, channels, schedules, and asynchronous BRIDGE.

Covers the subsystem's contract surface:
* mailbox staleness masking and out-of-order delivery;
* determinism of drop/latency traces under a fixed PRNG key;
* bit-for-bit equivalence with the synchronous `core.bridge` path under an
  ideal channel (the acceptance bar for the runtime refactor);
* resilience through partition-and-heal and lossy channels (async BRIDGE-T
  beats the no-screening mean baseline under the ALIE attack);
* message-attack registry validation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BridgeConfig,
    BridgeTrainer,
    erdos_renyi,
    get_attack,
    get_message_attack,
    replicate,
    ring_of_cliques,
)
from repro.net import (
    AsyncBridgeConfig,
    AsyncBridgeTrainer,
    ChannelConfig,
    SynchronousRuntime,
    UnreliableRuntime,
    edge_churn,
    init_mailbox,
    node_join_leave,
    partition_and_heal,
    schedule_stats,
    static_schedule,
    usable_mask,
)
from repro.net import mailbox as mb

M, D = 16, 5


def quad_grad_fn(params, batch):
    w, c = params["w"], batch
    loss = 0.5 * jnp.sum((w - c) ** 2)
    return loss, {"w": w - c}


@pytest.fixture(scope="module")
def topo():
    return ring_of_cliques(4, 4, 1)


@pytest.fixture(scope="module")
def targets():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(M, D)), jnp.float32)


def _make(topo, rule, attack, *, channel, staleness_bound=5, schedule=None,
          b=1, lam=1.0, t0=10):
    cfg = AsyncBridgeConfig(
        topology=topo, rule=rule, num_byzantine=b, attack=attack, lam=lam,
        t0=t0, channel=channel, staleness_bound=staleness_bound, schedule=schedule,
    )
    tr = AsyncBridgeTrainer(cfg, quad_grad_fn)
    params = replicate({"w": jnp.zeros(D)}, topo.num_nodes, perturb=0.1,
                       key=jax.random.PRNGKey(0))
    return tr, tr.init(params)


# ---------------------------------------------------------------------------
# Mailbox semantics
# ---------------------------------------------------------------------------


def test_mailbox_staleness_masking():
    state = init_mailbox(3, 2, max_delay=2)
    msgs = jnp.arange(3 * 3 * 2, dtype=jnp.float32).reshape(3, 3, 2)
    send = jnp.ones((3, 3), bool)
    delay = jnp.full((3, 3), 2, jnp.int32)
    state = mb.push(state, msgs, send, delay, jnp.int32(0))
    # nothing delivered yet -> nothing usable
    state, arrived = mb.deliver(state, jnp.int32(0))
    assert not bool(arrived.any())
    assert not bool(usable_mask(state, jnp.int32(0), 10).any())
    # delivery happens at t=2; staleness counts from the *send* tick
    state, arrived = mb.deliver(state, jnp.int32(2))
    assert bool(arrived.all())
    np.testing.assert_array_equal(np.asarray(state.values), np.asarray(msgs))
    assert bool(usable_mask(state, jnp.int32(2), 2).all())
    # at t=5 the entries are 5 ticks past their send -> bound 5 keeps them,
    # bound 4 masks them all
    assert bool(usable_mask(state, jnp.int32(5), 5).all())
    assert not bool(usable_mask(state, jnp.int32(5), 4).any())


def test_mailbox_out_of_order_keeps_newest():
    state = init_mailbox(1, 1, max_delay=3)
    ones = jnp.ones((1, 1), bool)
    old = jnp.full((1, 1, 1), 10.0)
    new = jnp.full((1, 1, 1), 20.0)
    state = mb.push(state, old, ones, jnp.full((1, 1), 3, jnp.int32), jnp.int32(0))
    state = mb.push(state, new, ones, jnp.full((1, 1), 0, jnp.int32), jnp.int32(1))
    state, _ = mb.deliver(state, jnp.int32(1))  # newer message lands first
    assert float(state.values[0, 0, 0]) == 20.0
    state, arrived = mb.deliver(state, jnp.int32(3))  # stale copy arrives late
    assert bool(arrived[0, 0])
    assert float(state.values[0, 0, 0]) == 20.0  # not clobbered
    assert int(state.send_tick[0, 0]) == 1


def test_bandwidth_cap_backfills_self(topo):
    """Exactly `cap` coordinates travel each tick — a PRNG-sampled subset
    (NOT the old deterministic prefix; see tests/test_comm.py for the bias
    regression) — and the receiver backfills the rest with its own value."""
    ch = ChannelConfig(bandwidth_cap=2)
    rt = UnreliableRuntime(topo, ch, staleness_bound=5)
    m = topo.num_nodes
    net = rt.init(m, D)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(m, D)), jnp.float32)
    msgs = jnp.broadcast_to(w[None], (m, m, D))
    adj = jnp.asarray(topo.adjacency)
    seen = np.zeros(D, bool)
    for t in range(8):
        net, views, mask, _ = rt.exchange(
            net, msgs, w, adj, jax.random.PRNGKey(t), jnp.int32(t))
        views = np.asarray(views)
        j, i = map(int, np.argwhere(np.asarray(adj))[0])
        sent = np.isclose(views[j, i], np.asarray(w)[i])
        backfilled = np.isclose(views[j, i], np.asarray(w)[j])
        assert (sent | backfilled).all()  # every coord is sender's or self
        # mailbox entries persist across ticks, so the sender's value covers
        # at least this tick's 2 transmitted coords (monotone coverage)
        seen |= sent
    # different ticks transmit different subsets — coverage exceeds any
    # single tick's cap (the deterministic prefix mask could never do this)
    assert seen.sum() > 2


# ---------------------------------------------------------------------------
# Determinism
# ---------------------------------------------------------------------------


def test_drop_latency_determinism(topo, targets):
    ch = ChannelConfig(drop_prob=0.3, latency_min=0, latency_max=3)

    def run(seed):
        tr, st = _make(topo, "trimmed_mean", "random", channel=ch)
        st = st._replace(key=jax.random.PRNGKey(seed))
        st, ms = tr.run_ticks(st, lambda i: targets, 40)
        return np.asarray(st.params["w"]), np.asarray(ms["delivered_frac"])

    w1, d1 = run(0)
    w2, d2 = run(0)
    w3, d3 = run(1)
    np.testing.assert_array_equal(w1, w2)
    np.testing.assert_array_equal(d1, d2)
    assert not np.array_equal(d1, d3)  # different key -> different loss trace


# ---------------------------------------------------------------------------
# Equivalence with the synchronous path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", ["trimmed_mean", "median"])
def test_ideal_channel_matches_core_bridge_bitwise(targets, rule):
    """Acceptance bar: zero latency, zero drop, static graph -> the async
    runtime reproduces `core.bridge` iterates bit-for-bit over >= 50 ticks."""
    topo = erdos_renyi(M, 0.8, 2, seed=1)
    cfg = BridgeConfig(topology=topo, rule=rule, num_byzantine=2,
                       attack="random", lam=1.0, t0=10)
    sync = BridgeTrainer(cfg, quad_grad_fn)
    atr, ast = _make(topo, rule, "random", channel=ChannelConfig.ideal(),
                     staleness_bound=0, b=2)
    params = replicate({"w": jnp.zeros(D)}, M, perturb=0.1, key=jax.random.PRNGKey(0))
    st = sync.init(params)
    for _ in range(55):
        st, _ = sync.step(st, targets)
        ast, _ = atr.step(ast, targets)
        np.testing.assert_array_equal(np.asarray(st.params["w"]),
                                      np.asarray(ast.params["w"]))


def test_synchronous_runtime_matches_default_path(targets):
    """The runtime= hook with the trivial runtime is the identity refactor."""
    topo = erdos_renyi(M, 0.8, 2, seed=1)
    cfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                       attack="alie", lam=1.0, t0=10)
    base = BridgeTrainer(cfg, quad_grad_fn)
    hooked = BridgeTrainer(cfg, quad_grad_fn, runtime=SynchronousRuntime(topo))
    params = replicate({"w": jnp.zeros(D)}, M, perturb=0.1, key=jax.random.PRNGKey(0))
    s1, s2 = base.init(params), hooked.init(params)
    for _ in range(30):
        s1, _ = base.step(s1, targets)
        s2, _ = hooked.step(s2, targets)
    np.testing.assert_array_equal(np.asarray(s1.params["w"]),
                                  np.asarray(s2.params["w"]))


# ---------------------------------------------------------------------------
# Resilience under network stress (paper claims x network conditions)
# ---------------------------------------------------------------------------


def _honest_stats(tr, targets):
    hm = np.asarray(tr.honest_mask)
    t = np.asarray(targets)[hm]
    c = t.mean(0)
    opt = 0.5 * float(np.mean(np.sum((t - c) ** 2, axis=1)))
    return hm, c, opt


def test_partition_and_heal_convergence(topo, targets):
    """Async BRIDGE-T rides out a partition (two halves of the clique ring
    severed for 70 ticks) and still reaches consensus near the honest mean
    after the network heals."""
    groups = np.repeat(np.arange(2), M // 2)
    sched = partition_and_heal(topo, 400, groups, cut_start=50, cut_end=120)
    ch = ChannelConfig(drop_prob=0.1, latency_min=0, latency_max=2)
    tr, st = _make(topo, "trimmed_mean", "random", channel=ch, schedule=sched)
    st, ms = tr.run_ticks(st, lambda i: targets, 400)
    hm, c, opt = _honest_stats(tr, targets)
    w_fin = np.asarray(st.params["w"])[hm]
    assert float(ms["consensus_dist"][-1]) < 0.5
    assert np.linalg.norm(w_fin.mean(0) - c) < 0.8
    assert float(ms["loss"][-1]) < opt + 1.0


def test_lossy_alie_bridge_beats_mean_baseline(topo, targets):
    """Acceptance bar: 20% drop + staleness bound 5 on ring-of-cliques under
    the ALIE attack — async BRIDGE-T drives train loss below the
    no-screening mean baseline."""
    ch = ChannelConfig(drop_prob=0.2)
    tr_t, st_t = _make(topo, "trimmed_mean", "alie", channel=ch, staleness_bound=5)
    st_t, ms_t = tr_t.run_ticks(st_t, lambda i: targets, 300)
    tr_m, st_m = _make(topo, "mean", "alie", channel=ch, staleness_bound=5)
    st_m, ms_m = tr_m.run_ticks(st_m, lambda i: targets, 300)
    assert float(ms_t["loss"][-1]) < float(ms_m["loss"][-1])
    # and BRIDGE-T itself lands near the honest optimum
    _, _, opt = _honest_stats(tr_t, targets)
    assert float(ms_t["loss"][-1]) < opt + 1.0


def test_selective_victim_screened(topo, targets):
    """The per-neighbor selective-victim attack (message granularity) is still
    screened by async BRIDGE-T."""
    ch = ChannelConfig(drop_prob=0.1, latency_min=0, latency_max=1)
    tr, st = _make(topo, "trimmed_mean", "selective_victim", channel=ch)
    st, ms = tr.run_ticks(st, lambda i: targets, 300)
    hm, c, opt = _honest_stats(tr, targets)
    w_fin = np.asarray(st.params["w"])[hm]
    assert np.linalg.norm(w_fin.mean(0) - c) < 1.0
    assert float(ms["loss"][-1]) < opt + 1.0


# ---------------------------------------------------------------------------
# Schedules + registry validation
# ---------------------------------------------------------------------------


def test_schedule_generators_shapes(topo):
    T, m = 30, topo.num_nodes
    s = static_schedule(topo, T)
    assert s.shape == (T, m, m) and s.all(axis=0).sum() == topo.adjacency.sum()
    churn = edge_churn(topo, T, 0.4, seed=0)
    assert churn.shape == (T, m, m)
    assert (churn <= s).all()  # churn only removes edges
    assert (churn == churn.transpose(0, 2, 1)).all()  # symmetric churn
    jl = node_join_leave(topo, T, {0: (5, 15)})
    assert not jl[5:15, 0].any() and not jl[5:15, :, 0].any()
    assert jl[4, 0].any() and jl[15, 0].any()
    stats = schedule_stats(churn)
    assert 0.0 < stats["edge_uptime"] < 1.0
    assert stats["min_in_degree"] <= stats["mean_in_degree"]


def test_attack_registry_validation():
    assert get_message_attack("selective_victim").name == "selective_victim"
    # every broadcast attack lifts to a message attack
    for name in ["none", "random", "alie"]:
        assert get_message_attack(name).broadcast is not None
    with pytest.raises(ValueError, match="network runtime"):
        get_attack("selective_victim")
    with pytest.raises(ValueError, match="selective_victim"):
        get_message_attack("definitely_not_an_attack")
    with pytest.raises(ValueError, match="options"):
        get_attack("definitely_not_an_attack")
