"""repro.obs.metrics: live metric rings, the chunked runner, and the writer.

The live-telemetry contract (ISSUE 9 acceptance):
* (a) metrics OFF is structurally absent — ``state.mets is None`` and the
  programs are the exact pre-feature ones (params + metric streams bitwise
  equal to a build that never mentions metrics), across rule x attack x
  codec, sync + net paths, flat + stream trainers;
* (b) metrics ON is BIT-INERT — the ring only reads values the step already
  computes, so the trajectory is bitwise unchanged;
* (c) ``run_chunks`` (host loop over jitted scan chunks with donated
  carries) is bitwise identical to step-at-a-time execution, including
  ragged tails, and refuses chunks that would overwrite unflushed ticks;
* (d) the background `MetricWriter` streams a gapless, deduped row set and
  ``close()`` drains durably; threshold alerts land as ``obs.alert`` events;
plus unit coverage of the ring decode, the alert engine, and the EventLog
batching/close semantics (ISSUE 9 satellites a+b).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BridgeConfig, BridgeTrainer, erdos_renyi, replicate
from repro.core.bridge import stack_batches
from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer, ChannelConfig
from repro.obs import EventLog, read_events
from repro.obs.metrics import (COLUMNS, AlertEngine, AlertRules, MetricSpec,
                               MetricWriter, init_state, read_metrics, rows_of,
                               update)
from repro.sim import ExperimentGrid, GridEngine
from repro.stream import StreamBridgeTrainer

M, D, T = 12, 5, 25


def quad_grad_fn(params, batch):
    w, c = params["w"], batch
    loss = 0.5 * jnp.sum((w - c) ** 2)
    return loss, {"w": w - c}


@pytest.fixture(scope="module")
def topo():
    return erdos_renyi(M, 0.8, 2, seed=1)


@pytest.fixture(scope="module")
def targets():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(M, D)), jnp.float32)


def init_fn(seed):
    return replicate({"w": jnp.zeros(D)}, M, perturb=0.1, key=jax.random.PRNGKey(seed))


def _sync_run(topo, targets, *, rule="trimmed_mean", attack="alie",
              codec="identity", metrics=None, stream=False, ticks=T):
    cfg = BridgeConfig(topology=topo, rule=rule, num_byzantine=2, attack=attack,
                       codec=codec, lam=1.0, t0=10.0, metrics=metrics)
    cls = StreamBridgeTrainer if stream else BridgeTrainer
    tr = cls(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    streams = {"loss": [], "consensus_dist": []}
    for _ in range(ticks):
        st, m = tr.step(st, targets)
        for k in streams:
            streams[k].append(m[k])
    return tr, st, {k: np.asarray(jnp.stack(v)) for k, v in streams.items()}


def _net_run(topo, batches, *, metrics=None):
    cfg = AsyncBridgeConfig(
        topology=topo, rule="trimmed_mean", num_byzantine=2, attack="alie",
        channel=ChannelConfig(drop_prob=0.1), staleness_bound=2,
        lam=1.0, t0=10.0, metrics=metrics)
    tr = AsyncBridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    st, ms = tr.run_scan(st, batches)
    return tr, st, ms


def _col(buf, name):
    return np.asarray(buf)[:, COLUMNS.index(name)]


# ---------------------------------------------------------------------------
# the ring itself
# ---------------------------------------------------------------------------


def test_spec_is_zero_leaf_structure():
    assert jax.tree_util.tree_leaves(MetricSpec()) == []
    with pytest.raises(ValueError):
        MetricSpec(capacity=0)


def test_ring_wraparound_and_decode():
    spec = MetricSpec(capacity=4)
    st = init_state(spec)
    for t in range(10):
        st = update(spec, st, t=t, vals={"loss": float(t), "consensus_dist": 0.1})
    assert int(st.count) == 10
    rows = rows_of(st.buf, st.count)
    # the ring keeps the LAST capacity ticks, tick-ordered
    assert [r["tick"] for r in rows] == [6, 7, 8, 9]
    assert [r["loss"] for r in rows] == [6.0, 7.0, 8.0, 9.0]
    # dedup across overlapping flushes: `after` drops already-written ticks
    assert [r["tick"] for r in rows_of(st.buf, st.count, after=7)] == [8, 9]
    # absent columns hold NaN on device and decode as None (JSON null)
    assert rows[0]["evicted_frac"] is None
    assert rows[0]["stale_p50"] is None


def test_short_first_chunk_skips_unwritten_slots():
    spec = MetricSpec(capacity=8)
    st = init_state(spec)
    for t in range(3):
        st = update(spec, st, t=t, vals={"loss": 1.0, "consensus_dist": 0.0})
    assert [r["tick"] for r in rows_of(st.buf, st.count)] == [0, 1, 2]


def test_nonfinite_sentinel_column():
    spec = MetricSpec(capacity=2)
    st = init_state(spec)
    st = update(spec, st, t=0, vals={"loss": 1.0, "consensus_dist": 0.0})
    st = update(spec, st, t=1, vals={"loss": float("nan"), "consensus_dist": 0.0})
    rows = rows_of(st.buf, st.count)
    assert rows[0]["nonfinite"] == 0.0
    assert rows[1]["nonfinite"] == 1.0
    assert rows[1]["loss"] is None  # NaN loss itself renders as null


# ---------------------------------------------------------------------------
# (a)+(b) metrics off = absent; metrics on = bit-inert
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule,attack,codec,stream", [
    ("trimmed_mean", "alie", "identity", False),
    ("trimmed_mean", "sign_flip", "int8", False),
    ("median", "random", "identity", False),
    ("trimmed_mean", "alie", "identity", True),
    ("median", "sign_flip", "int8", True),
])
def test_sync_metrics_bit_inert(topo, targets, rule, attack, codec, stream):
    """The ring compiled into the step changes NOTHING about the trajectory,
    on both the flat and the chunk-streaming trainer."""
    _, st_off, ms_off = _sync_run(topo, targets, rule=rule, attack=attack,
                                  codec=codec, metrics=None, stream=stream)
    _, st_on, ms_on = _sync_run(topo, targets, rule=rule, attack=attack,
                                codec=codec, metrics=MetricSpec(capacity=T),
                                stream=stream)
    assert st_off.mets is None
    np.testing.assert_array_equal(np.asarray(st_off.params["w"]),
                                  np.asarray(st_on.params["w"]))
    for k in ms_off:
        np.testing.assert_array_equal(ms_off[k], ms_on[k],
                                      err_msg=f"metric {k} diverged under metrics")
    # and the ring actually observed the run
    assert int(st_on.mets.count) == T
    rows = rows_of(st_on.mets.buf, st_on.mets.count)
    np.testing.assert_allclose([r["loss"] for r in rows], ms_on["loss"],
                               rtol=1e-6)
    assert all(r["grad_norm"] is not None and r["grad_norm"] > 0 for r in rows)


def test_net_metrics_bit_inert_and_staleness_columns(topo, targets):
    """The network-runtime path: bitwise unchanged, and the delivered-message
    staleness quantiles populate (NaN on the sync path)."""
    batches = stack_batches(lambda i: targets, T)
    _, st_off, ms_off = _net_run(topo, batches, metrics=None)
    _, st_on, ms_on = _net_run(topo, batches, metrics=MetricSpec(capacity=T))
    assert st_off.mets is None
    np.testing.assert_array_equal(np.asarray(st_off.params["w"]),
                                  np.asarray(st_on.params["w"]))
    np.testing.assert_array_equal(np.asarray(ms_off["loss"]),
                                  np.asarray(ms_on["loss"]))
    p50 = _col(st_on.mets.buf, "stale_p50")
    assert np.isfinite(p50).any(), "net path should fill staleness quantiles"


# ---------------------------------------------------------------------------
# (c) run_chunks == step-at-a-time, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stream,chunk", [(False, 7), (False, T), (True, 7)])
def test_run_chunks_matches_step_loop(topo, targets, stream, chunk):
    """Chunked scans with donated carries (including a ragged tail: 25 = 3x7
    + 4) reproduce the step loop bit-for-bit, params AND metric streams."""
    _, st_step, ms_step = _sync_run(topo, targets,
                                    metrics=MetricSpec(capacity=T), stream=stream)
    cfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                       attack="alie", lam=1.0, t0=10.0,
                       metrics=MetricSpec(capacity=T))
    tr = (StreamBridgeTrainer if stream else BridgeTrainer)(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    st, ms = tr.run_chunks(st, lambda i: targets, T, chunk=chunk)
    np.testing.assert_array_equal(np.asarray(st_step.params["w"]),
                                  np.asarray(st.params["w"]))
    for k in ms_step:
        np.testing.assert_array_equal(ms_step[k], np.asarray(ms[k]))
    assert int(st.mets.count) == T


def test_run_chunks_rejects_chunk_beyond_capacity(topo, targets):
    cfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                       attack="alie", lam=1.0, t0=10.0,
                       metrics=MetricSpec(capacity=4))
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    with pytest.raises(ValueError, match="capacity"):
        tr.run_chunks(st, lambda i: targets, 8, chunk=6)
    with pytest.raises(ValueError):
        tr.run_chunks(st, lambda i: targets, 8, chunk=0)


def test_run_chunks_defaults_chunk_to_capacity(topo, targets):
    """No explicit chunk: the runner picks the ring capacity, so a writer
    flushing once per chunk never loses a tick."""
    cfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                       attack="alie", lam=1.0, t0=10.0,
                       metrics=MetricSpec(capacity=6))
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    st, ms = tr.run_chunks(st, lambda i: targets, T)
    assert int(st.mets.count) == T
    assert np.asarray(ms["loss"]).shape == (T,)


# ---------------------------------------------------------------------------
# (d) the background writer + the chunked runner, end to end
# ---------------------------------------------------------------------------


def test_writer_streams_gapless_rows(topo, targets, tmp_path):
    path = os.path.join(tmp_path, "metrics.jsonl")
    cfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                       attack="alie", lam=1.0, t0=10.0,
                       metrics=MetricSpec(capacity=8))
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    events_path = os.path.join(tmp_path, "events.jsonl")
    with EventLog(events_path) as ev, MetricWriter(path, events=ev) as w:
        st, _ = tr.run_chunks(st, lambda i: targets, T, writer=w, events=ev)
    rows = read_metrics(path)
    # gapless and deduped: exactly one row per tick, in order
    assert [r["tick"] for r in rows] == list(range(T))
    assert all(r["tag"] == "train" for r in rows)
    # per-row walls interpolate monotonically between flush walls
    walls = [r["wall"] for r in rows]
    assert walls == sorted(walls)
    # the runner logged one train.chunk event per dispatched chunk (25 = 3x8+1)
    chunks = [e for e in read_events(events_path) if e["tag"] == "train.chunk"]
    assert [(e["lo"], e["hi"]) for e in chunks] == [(0, 8), (8, 16), (16, 24),
                                                    (24, 25)]
    assert all(e["train_tag"] == "train" for e in chunks)


def test_writer_close_drains_durably(tmp_path):
    """Everything enqueued before close() is on disk after close() — the
    writer joins its drain thread instead of dropping the queue."""
    spec = MetricSpec(capacity=16)
    st = init_state(spec)
    for t in range(16):
        st = update(spec, st, t=t, vals={"loss": 1.0, "consensus_dist": 0.0})
    path = os.path.join(tmp_path, "m.jsonl")
    w = MetricWriter(path)
    w.flush(st, tag="a")
    w.flush(st, tag="b")
    w.flush(st, tag="a")  # dedup: same ticks again write nothing
    w.close()
    assert w.rows_written == 32
    rows = read_metrics(path)
    assert len(rows) == 32
    assert len(read_metrics(path, tag="a")) == 16
    assert len(read_metrics(path, after=9, tag="b")) == 6
    w.close()  # idempotent
    w.flush(st, tag="c")  # post-close flush is a no-op, not a crash
    assert len(read_metrics(path)) == 32


def test_writer_emits_alert_events(tmp_path):
    """A divergent row crosses the writer -> AlertEngine -> EventLog path as
    an ``obs.alert`` record whose stream tag rides a non-colliding field."""
    spec = MetricSpec(capacity=4)
    st = init_state(spec)
    st = update(spec, st, t=0, vals={"loss": 1.0, "consensus_dist": 0.0})
    st = update(spec, st, t=1, vals={"loss": float("nan"), "consensus_dist": 0.0})
    mpath = os.path.join(tmp_path, "m.jsonl")
    epath = os.path.join(tmp_path, "e.jsonl")
    with EventLog(epath) as ev:
        with MetricWriter(mpath, alerts=AlertRules(), events=ev) as w:
            w.flush(st, tag="cell0")
    alerts = [e for e in read_events(epath) if e["tag"] == "obs.alert"]
    assert len(alerts) == 1
    assert alerts[0]["kind"] == "divergence"
    assert alerts[0]["stream"] == "cell0"
    assert alerts[0]["tick"] == 1


def test_grid_engine_streams_per_cell_tags(topo, targets, tmp_path):
    """The grid engine flushes a stacked [E] ring batch with per-cell tags
    (engine cell order, not compile order).  Grid cells scan all their ticks
    inside one compiled bank, so each stream is the documented TAIL window of
    the last ``capacity`` ticks — capacity >= ticks makes it gapless."""
    grid = ExperimentGrid(topo, ("trimmed_mean", "median"), ("alie",), (2,),
                          (0, 1), lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn, metrics=MetricSpec(capacity=8))
    state = engine.init(init_fn)
    batches = stack_batches(lambda i: targets, 8)
    path = os.path.join(tmp_path, "m.jsonl")
    with MetricWriter(path) as w:
        final, _ = engine.run(state, batches, chunk=3, metric_writer=w)
    rows = read_metrics(path)
    tags = {c.tag for c in engine.cells}
    assert len(tags) == 4
    assert {r["tag"] for r in rows} == tags
    for tag in tags:
        assert [r["tick"] for r in read_metrics(path, tag=tag)] == list(range(8))


def test_grid_engine_small_ring_keeps_tail(topo, targets, tmp_path):
    grid = ExperimentGrid(topo, ("trimmed_mean",), ("alie",), (2,), (0,),
                          lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn, metrics=MetricSpec(capacity=4))
    state = engine.init(init_fn)
    path = os.path.join(tmp_path, "m.jsonl")
    with MetricWriter(path) as w:
        engine.run(state, stack_batches(lambda i: targets, 8), metric_writer=w)
    assert [r["tick"] for r in read_metrics(path)] == [4, 5, 6, 7]


def test_grid_engine_rejects_writer_without_spec(topo, targets, tmp_path):
    grid = ExperimentGrid(topo, ("trimmed_mean",), ("alie",), (2,), (0,),
                          lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn)
    state = engine.init(init_fn)
    with MetricWriter(os.path.join(tmp_path, "m.jsonl")) as w:
        with pytest.raises(ValueError, match="metrics"):
            engine.run(state, stack_batches(lambda i: targets, 4),
                       metric_writer=w)


# ---------------------------------------------------------------------------
# the alert engine (shared by writer and live monitor)
# ---------------------------------------------------------------------------


def test_alert_engine_latches_per_kind():
    eng = AlertEngine(AlertRules())
    row_bad = {"tick": 3, "nonfinite": 1.0}
    assert [a["kind"] for a in eng.feed("t", row_bad)] == ["divergence"]
    assert eng.feed("t", dict(row_bad, tick=4)) == []  # latched
    # an independent stream tag fires its own alert
    assert [a["kind"] for a in eng.feed("u", row_bad)] == ["divergence"]


def test_alert_engine_loss_spike_tracks_running_min():
    eng = AlertEngine(AlertRules(loss_spike_factor=10.0))
    assert eng.feed("t", {"tick": 0, "loss": 5.0}) == []
    assert eng.feed("t", {"tick": 1, "loss": 1.0}) == []
    assert eng.feed("t", {"tick": 2, "loss": 9.0}) == []  # < 10x min
    out = eng.feed("t", {"tick": 3, "loss": 11.0})
    assert out[0]["kind"] == "loss_spike" and out[0]["running_min"] == 1.0


def test_alert_engine_eviction_and_wire_budget():
    eng = AlertEngine(AlertRules(evict_spike=0.2, wire_budget_bytes=100.0))
    out = eng.feed("t", {"tick": 0, "evicted_frac": 0.5,
                         "wire_bytes_total": 60.0})
    assert [a["kind"] for a in out] == ["eviction_spike"]
    out = eng.feed("t", {"tick": 1, "evicted_frac": 0.5,
                         "wire_bytes_total": 60.0})  # cumulative 120 > 100
    assert [a["kind"] for a in out] == ["wire_budget"]
    assert out[0]["wire_bytes_cumulative"] == 120.0


# ---------------------------------------------------------------------------
# EventLog batching + close semantics (ISSUE 9 satellites a+b)
# ---------------------------------------------------------------------------


def test_eventlog_close_drains_batched_queue(tmp_path):
    """With a long flush interval nothing may have hit the disk yet; close()
    must still drain every queued record durably before returning."""
    path = os.path.join(tmp_path, "e.jsonl")
    log = EventLog(path, flush_interval=60.0)
    for i in range(200):
        log.emit("unit.test", i=i)
    log.close()
    recs = read_events(path)
    assert [r["i"] for r in recs] == list(range(200))
    log.close()  # idempotent
    log.emit("unit.test", i=999)  # post-close emit is a no-op
    assert len(read_events(path)) == 200


def test_eventlog_records_are_json_lines(tmp_path):
    path = os.path.join(tmp_path, "e.jsonl")
    with EventLog(path) as log:
        log.emit("a.b", x=1.5, s="hi")
    with open(path) as f:
        rec = json.loads(f.readline())
    assert rec["tag"] == "a.b" and rec["x"] == 1.5 and rec["s"] == "hi"
    assert "wall" in rec and "time" in rec
