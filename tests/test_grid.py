"""repro.sim: the batched experiment-grid engine.

The engine's contract (ISSUE 2 acceptance):
* (a) every grid cell is bit-identical to the sequential per-experiment
  trainer (`BridgeTrainer` / `AsyncBridgeTrainer`) — params AND metric
  traces — for both the grouped and the fully banked execution paths;
* (b) chunked and unchunked grids agree bit-for-bit;
* (c) the full grid compiles ONCE (trace-count assertion), and chunking
  compiles per group, never per cell;
plus spec validation, the result store round-trip, and the batched
(leading-experiment-axis) kernels.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BridgeConfig, BridgeTrainer, erdos_renyi, replicate
from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer
from repro.net.scenarios import get_scenario
from repro.sim import Cell, ExperimentGrid, GridEngine, GridResult, collect, existing_tags
from repro.sim.engine import stack_batches

M, D, T = 12, 5, 25


def quad_grad_fn(params, batch):
    w, c = params["w"], batch
    loss = 0.5 * jnp.sum((w - c) ** 2)
    return loss, {"w": w - c}


@pytest.fixture(scope="module")
def topo():
    return erdos_renyi(M, 0.8, 2, seed=1)


@pytest.fixture(scope="module")
def targets():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(M, D)), jnp.float32)


def init_fn(seed):
    return replicate({"w": jnp.zeros(D)}, M, perturb=0.1, key=jax.random.PRNGKey(seed))


@pytest.fixture(scope="module")
def batches(targets):
    return stack_batches(lambda i: targets, T)


def _sequential_sync(topo, targets, cell):
    # the cell's mask_seed (seed-axis-varying since ISSUE 4) maps onto the
    # trainer's byzantine_seed — same draw, same attacking nodes
    cfg = BridgeConfig(topology=topo, rule=cell.rule, num_byzantine=cell.b,
                       attack=cell.attack, lam=1.0, t0=10.0,
                       byzantine_seed=cell.mask_seed if cell.mask_seed is not None else 0)
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(cell.seed), seed=cell.seed)
    losses = []
    for _ in range(T):
        st, m = tr.step(st, targets)
        losses.append(m["loss"])
    return np.asarray(st.params["w"]), np.asarray(jnp.stack(losses))


# ---------------------------------------------------------------------------
# (a) per-cell bit-identity with the sequential trainers
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("group", [True, False])
def test_sync_grid_bit_equals_sequential_trainer(topo, targets, batches, group):
    """The acceptance grid — 2 rules x 3 attacks x 4 seeds — as one compiled
    program, every cell bit-for-bit equal to its own BridgeTrainer run."""
    grid = ExperimentGrid(topo, ("trimmed_mean", "median"),
                          ("random", "sign_flip", "alie"), (2,), (0, 1, 2, 3),
                          lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn, group=group)
    state = engine.init(init_fn)
    final, metrics = engine.run(state, batches)
    assert engine.num_cells == 24
    for i, cell in enumerate(engine.cells):
        w_seq, loss_seq = _sequential_sync(topo, targets, cell)
        np.testing.assert_array_equal(w_seq, np.asarray(final.params["w"][i]),
                                      err_msg=f"params diverged for {cell}")
        np.testing.assert_array_equal(loss_seq, np.asarray(metrics["loss"][i]),
                                      err_msg=f"loss trace diverged for {cell}")


@pytest.mark.slow
def test_net_grid_bit_equals_async_trainer(topo, targets, batches):
    """Net-scenario cells (channel noise, churn, per-link attacks) are
    bit-identical to dedicated AsyncBridgeTrainer runs driven with the same
    schedules."""
    grid = ExperimentGrid(topo, ("trimmed_mean",), ("random", "selective_victim"),
                          (2,), (0, 1), scenarios=("ideal", "lossy_laggy", "churn"),
                          lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn, num_ticks=T)
    state = engine.init(init_fn)
    final, metrics = engine.run(state, batches)
    for i, cell in enumerate(engine.cells):
        spec = get_scenario(cell.scenario)
        cfg = AsyncBridgeConfig(
            topology=topo, rule=cell.rule, num_byzantine=cell.b, attack=cell.attack,
            lam=1.0, t0=10.0, channel=spec.channel,
            staleness_bound=spec.staleness_bound,
            schedule=engine.runtime.schedule_for(cell.scenario),
            byzantine_seed=cell.mask_seed if cell.mask_seed is not None else 0,
        )
        tr = AsyncBridgeTrainer(cfg, quad_grad_fn)
        st = tr.init(init_fn(cell.seed), seed=cell.seed)
        st, ms = tr.run_scan(st, batches)
        np.testing.assert_array_equal(np.asarray(st.params["w"]),
                                      np.asarray(final.params["w"][i]),
                                      err_msg=f"params diverged for {cell}")
        np.testing.assert_array_equal(np.asarray(ms["loss"]),
                                      np.asarray(metrics["loss"][i]),
                                      err_msg=f"loss trace diverged for {cell}")


# ---------------------------------------------------------------------------
# (b) chunked == unchunked
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("chunk", [1, 3, 5, 24])
def test_chunked_matches_unchunked(topo, targets, batches, chunk):
    grid = ExperimentGrid(topo, ("trimmed_mean", "median"),
                          ("random", "sign_flip", "alie"), (2,), (0, 1, 2, 3),
                          lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn)
    state = engine.init(init_fn)
    full, ms_full = engine.run(state, batches)
    part, ms_part = engine.run(state, batches, chunk=chunk)
    np.testing.assert_array_equal(np.asarray(full.params["w"]),
                                  np.asarray(part.params["w"]))
    for k in ms_full:
        np.testing.assert_array_equal(np.asarray(ms_full[k]), np.asarray(ms_part[k]),
                                      err_msg=f"metric {k} diverged under chunking")


# ---------------------------------------------------------------------------
# (c) compile-once
# ---------------------------------------------------------------------------


def test_full_grid_compiles_once(topo, targets, batches):
    grid = ExperimentGrid(topo, ("trimmed_mean", "median"),
                          ("random", "sign_flip", "alie"), (2,), (0, 1, 2, 3),
                          lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn)
    state = engine.init(init_fn)
    assert engine.trace_count == 0
    engine.run(state, batches)
    assert engine.trace_count == 1  # 24 experiments, one compilation
    engine.run(state, batches)
    assert engine.trace_count == 1  # steady state: no retrace


def test_chunked_compiles_per_group_not_per_cell(topo, targets, batches):
    grid = ExperimentGrid(topo, ("trimmed_mean",), ("random",), (2,),
                          tuple(range(8)), lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn)
    state = engine.init(init_fn)
    engine.run(state, batches, chunk=3)  # 3 chunks (3+3+2, tail padded)
    assert engine.trace_count == 1  # one group -> one compilation, not 3
    engine.run(state, batches, chunk=3)
    assert engine.trace_count == 1


# ---------------------------------------------------------------------------
# spec validation + result store
# ---------------------------------------------------------------------------


def test_grid_validation(topo):
    with pytest.raises(ValueError, match="network runtime"):
        ExperimentGrid(topo, ("trimmed_mean",), ("selective_victim",))  # sync grid
    with pytest.raises(ValueError, match="min in-degree"):
        ExperimentGrid(topo, ("bulyan",), ("random",), byzantine_counts=(4,))
    with pytest.raises(ValueError, match="duplicate"):
        ExperimentGrid(topo, ("trimmed_mean", "trimmed_mean"), ("random",))
    with pytest.raises(ValueError, match="unknown net scenario"):
        ExperimentGrid(topo, ("trimmed_mean",), ("random",), scenarios=("5g",))
    grid = ExperimentGrid(topo, ("trimmed_mean",), ("random",))
    with pytest.raises(ValueError, match="num_ticks"):
        GridEngine(ExperimentGrid(topo, ("trimmed_mean",), ("random",),
                                  scenarios=("lossy",)), quad_grad_fn)
    mixed = [Cell("trimmed_mean", "random", 1, 0, None),
             Cell("trimmed_mean", "random", 1, 0, "lossy")]
    with pytest.raises(ValueError, match="mix"):
        GridEngine(grid, quad_grad_fn, cells=mixed)


def test_grid_result_store_roundtrip(tmp_path, topo, targets, batches):
    grid = ExperimentGrid(topo, ("trimmed_mean",), ("random",), (2,), (0, 1),
                          lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn)
    state = engine.init(init_fn)
    _, metrics = engine.run(state, batches)
    result = collect(engine.cells, metrics, meta={"ticks": T})
    assert len(result.cells) == 2
    assert all(np.isfinite(rec["final_loss"]) for rec in result.cells)
    path = tmp_path / "GridResult.json"
    result.save(str(path))
    loaded = GridResult.load(str(path))
    assert loaded.cells == result.cells and loaded.meta["ticks"] == T
    # per-cell store: resumability skips exactly the computed cells
    store = tmp_path / "cells"
    result.save_cells(str(store))
    tags = existing_tags(str(store))
    assert tags == {c.tag for c in engine.cells}
    pending = [c for c in grid.cells() if c.tag not in tags]
    assert pending == []
    assert len(result.rows(prefix="g")) == 2
    assert result.rows()[0][0].startswith("grid/")


# ---------------------------------------------------------------------------
# kernels: leading experiment axis
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("e,n,d,b", [(3, 9, 130, 1), (5, 12, 257, 2)])
def test_batched_kernels_match_per_experiment(e, n, d, b):
    from repro.kernels import ops, ref

    rng = np.random.default_rng(e * d)
    v = jnp.asarray(rng.normal(size=(e, n, d)), jnp.float32)
    mask = jnp.asarray(rng.random((e, n)) < 0.8).at[:, : 2 * b + 1].set(True)
    sv = jnp.asarray(rng.normal(size=(e, d)), jnp.float32)
    out = ops.trimmed_mean(v, mask, sv, b, block_d=128)
    assert out.shape == (e, d)
    exp = ref.trimmed_mean_ref(v, mask, sv, b)  # vmapped oracle
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5, atol=1e-5)
    for i in range(e):  # and the batch axis changes nothing per slice
        one = ops.trimmed_mean(v[i], mask[i], sv[i], b, block_d=128)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(one),
                                   rtol=1e-6, atol=1e-6)
    om = ops.median(v, mask, block_d=128)
    em = ref.median_ref(v, mask)
    assert om.shape == (e, d)
    np.testing.assert_allclose(np.asarray(om), np.asarray(em), rtol=1e-5, atol=1e-5)
