"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Property-style coverage uses a fixed seeded case grid (no ``hypothesis`` in
this environment).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,d,b", [(8, 64, 1), (16, 700, 3), (25, 1024, 4), (12, 513, 0), (9, 31, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_trimmed_mean_kernel(n, d, b, dtype):
    rng = np.random.default_rng(n * d + b)
    v = jnp.asarray(rng.normal(size=(n, d)), dtype)
    mask = jnp.asarray(rng.random(n) < 0.8)
    if int(mask.sum()) < 2 * b + 1:
        mask = jnp.ones((n,), bool)
    sv = jnp.asarray(rng.normal(size=(d,)), dtype)
    out = ops.trimmed_mean(v, mask, sv, b, block_d=256)
    exp = ref.trimmed_mean_ref(v, mask, sv, b)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,d", [(5, 100), (16, 512), (23, 777)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_median_kernel(n, d, dtype):
    rng = np.random.default_rng(n + d)
    v = jnp.asarray(rng.normal(size=(n, d)), dtype)
    mask = jnp.asarray(rng.random(n) < 0.7).at[0].set(True)
    out = ops.median(v, mask, block_d=256)
    exp = ref.median_ref(v, mask)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exp, np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,d", [(8, 200), (20, 1024), (33, 600)])
def test_krum_dists_kernel(n, d):
    rng = np.random.default_rng(d)
    v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    out = ops.pairwise_sq_dists(v, block_d=256)
    exp = ref.pairwise_sq_dists_ref(v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("n,d,b,seed", [
    (5, 1, 0, 0), (5, 3, 3, 1), (6, 17, 2, 2), (7, 128, 3, 3), (8, 47, 1, 4),
    (9, 255, 0, 5), (11, 129, 2, 6), (13, 300, 3, 7), (15, 64, 1, 8),
    (16, 200, 0, 9), (17, 5, 3, 10), (18, 257, 2, 11), (19, 96, 1, 12),
    (20, 300, 3, 13), (20, 1, 2, 14),
])
def test_trimmed_mean_property(n, d, b, seed):
    if n < 2 * b + 1:
        b = (n - 1) // 2
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    mask = jnp.ones((n,), bool)
    sv = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    out = ops.trimmed_mean(v, mask, sv, b, block_d=128)
    exp = ref.trimmed_mean_ref(v, mask, sv, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-4, atol=2e-4)
