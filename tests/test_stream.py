"""repro.stream — chunk-streaming pytree screening.

Pins the subsystem's contracts:

* `BlockSpec` partitions cover every coordinate exactly once, in
  `stack_flatten` order, with exact (unpadded) tail blocks.
* **Single block** (one leaf, chunk >= d): bitwise equality with the flat
  `BridgeTrainer` across the full rule x attack x codec product, including
  stochastic attacks and stochastic-rounding codecs (the per-block PRNG key
  is the step subkey itself).
* **Many blocks**: bitwise equality for every deterministic attack/codec
  combination, on multi-leaf mixed-dtype pytrees, at any chunk width.
* Trust/forensics: the decide path streams (per-block trim evidence folds
  into one [M, W] carry) — bitwise vs flat at a single block, and the
  trajectory stays exact under chunking for deterministic combos.
* The network path: ideal channel == streaming broadcast bitwise; lossy
  channels deliver/starve sanely.
* HLO: the streaming step's largest tensor stays strictly below the flat
  [M, d] f32 matrix at multi-leaf d — the [M, K, chunk] memory claim.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import screening
from repro.core.bridge import BridgeConfig, BridgeTrainer, replicate, stack_flatten
from repro.core.graph import erdos_renyi
from repro.stream import BlockSpec, StreamBridgeTrainer, StreamChannelConfig

M, B = 8, 1
TOPO = erdos_renyi(M, 0.9, B, seed=1)


def _params_single(d=24):
    p0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (d,))}
    return replicate(p0, M, perturb=0.1, key=jax.random.PRNGKey(1))


def _params_multi():
    """Three leaves, mixed bf16/f32, sizes that don't divide small chunks."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    p0 = {
        "emb": jax.random.normal(k1, (5, 3), jnp.float32),
        "w": jax.random.normal(k2, (7,), jnp.bfloat16),
        "b": jax.random.normal(k3, ()),
    }
    return replicate(p0, M, perturb=0.1, key=jax.random.PRNGKey(1))


def _task(params):
    targets = jax.tree_util.tree_map(
        lambda l: jax.random.normal(jax.random.PRNGKey(9), l.shape,
                                    jnp.float32).astype(l.dtype), params)

    def grad_fn(p, batch):
        diffs = jax.tree_util.tree_map(
            lambda a, t: a.astype(jnp.float32) - t.astype(jnp.float32), p, batch)
        loss = sum(0.5 * jnp.sum(d * d) for d in jax.tree_util.tree_leaves(diffs))
        grads = jax.tree_util.tree_map(lambda d, l: d.astype(l.dtype), diffs, p)
        return loss, grads

    return grad_fn, targets


def _run(trainer, params, batch, steps=4):
    state = trainer.init(params, seed=0)
    metrics = None
    for _ in range(steps):
        state, metrics = trainer.step(state, batch)
    return state, metrics


def _bitwise(a, b):
    return jax.tree_util.tree_all(
        jax.tree_util.tree_map(lambda x, y: bool(jnp.all(x == y)), a, b))


def _flat_vs_stream(params, steps=4, channel=None, flat_chunk=None, **cfg_kw):
    cfg_kw.setdefault("lr", 0.05)
    cfg_kw.setdefault("num_byzantine", B)
    grad_fn, targets = _task(params)
    cfg = BridgeConfig(topology=TOPO, **cfg_kw)
    # the flat reference may need an unchunked screen (e.g. forensics rejects
    # coordinate streaming — the restriction repro.stream lifts)
    fcfg = (cfg if flat_chunk is None
            else dataclasses.replace(cfg, screen_chunk=flat_chunk))
    fs, fm = _run(BridgeTrainer(fcfg, grad_fn), params, targets, steps)
    ss, sm = _run(StreamBridgeTrainer(cfg, grad_fn, channel=channel),
                  params, targets, steps)
    return fs, ss, fm, sm


# ---------------------------------------------------------------------------
# BlockSpec
# ---------------------------------------------------------------------------


def test_blockspec_partition_covers_stack_flatten_order():
    params = _params_multi()
    spec = BlockSpec.from_params(params, 4)
    sizes = spec.block_sizes()
    assert sum(sizes) == spec.total_dim == 15 + 7 + 1
    assert len(sizes) == spec.num_blocks
    assert max(sizes) == spec.max_block <= 4
    # per-leaf offsets line up with stack_flatten's concatenation order
    offsets = [p.offset for p in spec.leaves]
    leaf_sizes = [p.size for p in spec.leaves]
    assert offsets == [0, leaf_sizes[0], leaf_sizes[0] + leaf_sizes[1]]
    # tails are exact, never padded
    for p in spec.leaves:
        c = min(spec.chunk, p.size)
        assert p.num_full * c + p.tail == p.size


def test_blockspec_chunk_none_is_per_leaf():
    params = _params_multi()
    spec = BlockSpec.from_params(params, None)
    assert spec.num_blocks == len(spec.leaves)
    assert all(p.num_full == 1 and p.tail == 0 for p in spec.leaves)


def test_blockspec_rejects_int_leaves():
    bad = {"w": jnp.zeros((M, 4), jnp.int32)}
    with pytest.raises(ValueError, match="non-float"):
        BlockSpec.from_params(bad, 4)


def test_streaming_rejects_vector_rules():
    with pytest.raises(ValueError, match="not coordinate-decomposable"):
        screening.check_streamable(("trimmed_mean", "krum"))
    grad_fn, _ = _task(_params_single())
    cfg = BridgeConfig(topology=erdos_renyi(M, 1.0, B, seed=1), rule="geomedian",
                       num_byzantine=B)
    with pytest.raises(ValueError, match="not coordinate-decomposable"):
        StreamBridgeTrainer(cfg, grad_fn)


# ---------------------------------------------------------------------------
# Bit-identity vs the flat path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("attack", ["none", "random", "sign_flip", "alie",
                                    "same_value", "shift"])
def test_single_block_bitwise_all_attacks(attack):
    params = _params_single()
    fs, ss, _, _ = _flat_vs_stream(params, attack=attack, rule="trimmed_mean")
    assert _bitwise(fs.params, ss.params)


@pytest.mark.parametrize("codec", ["identity", "int8", "topk50", "randk25"])
def test_single_block_bitwise_all_codecs(codec):
    params = _params_single()
    fs, ss, fm, sm = _flat_vs_stream(params, attack="sign_flip", codec=codec,
                                     rule="trimmed_mean")
    assert _bitwise(fs.params, ss.params)
    assert float(fm["wire_bits_per_edge"]) == float(sm["wire_bits_per_edge"])
    assert np.isclose(float(fm["ef_residual_norm"]), float(sm["ef_residual_norm"]))


@pytest.mark.parametrize("rule", ["trimmed_mean", "median", "mean"])
def test_single_block_bitwise_rules_stochastic(rule):
    params = _params_single()
    fs, ss, _, _ = _flat_vs_stream(params, attack="random", rule=rule)
    assert _bitwise(fs.params, ss.params)


@pytest.mark.parametrize("attack", ["none", "sign_flip", "alie", "same_value",
                                    "shift"])
def test_multi_block_bitwise_deterministic_attacks(attack):
    params = _params_multi()
    fs, ss, _, _ = _flat_vs_stream(params, attack=attack, rule="trimmed_mean",
                                   screen_chunk=4)
    assert _bitwise(fs.params, ss.params)
    # dtypes preserved leaf-for-leaf (the streaming path inherits the
    # stack_flatten mixed-dtype guarantee by construction)
    for fl, sl in zip(jax.tree_util.tree_leaves(fs.params),
                      jax.tree_util.tree_leaves(ss.params), strict=True):
        assert fl.dtype == sl.dtype


@pytest.mark.parametrize("chunk", [1, 5, 64])
def test_chunk_width_invariance(chunk):
    """Deterministic combos give the same trajectory at ANY chunk width."""
    params = _params_multi()
    fs, ss, _, _ = _flat_vs_stream(params, attack="alie", rule="median",
                                   screen_chunk=chunk)
    assert _bitwise(fs.params, ss.params)


def test_sparse_streaming_bitwise():
    params = _params_single()
    fs, ss, _, _ = _flat_vs_stream(params, attack="random", rule="trimmed_mean",
                                   sparse=True)
    assert _bitwise(fs.params, ss.params)


def test_multi_block_sparse_deterministic_bitwise():
    params = _params_multi()
    fs, ss, _, _ = _flat_vs_stream(params, attack="sign_flip",
                                   rule="trimmed_mean", sparse=True,
                                   screen_chunk=3)
    assert _bitwise(fs.params, ss.params)


# ---------------------------------------------------------------------------
# Trust / forensics on the streaming path
# ---------------------------------------------------------------------------


def test_trust_single_block_bitwise():
    from repro.trust.reputation import TrustSpec

    params = _params_single()
    fs, ss, fm, sm = _flat_vs_stream(
        params, attack="sign_flip", rule="rep_trimmed_mean", sparse=True,
        trust=TrustSpec(echo=False))
    assert _bitwise(fs.params, ss.params)
    assert float(fm["trust_evicted_frac"]) == float(sm["trust_evicted_frac"])


def test_trust_multi_block_deterministic_bitwise():
    """Chunked trim evidence folds to the exact all-coordinate fraction
    (static block/d weights summing to 1), so even the *feedback* trajectory
    — reputation weights into the next tick's screening — stays close to the
    flat decide path; with the per-tick evidence aggregated from exact block
    fractions the trajectories agree to float tolerance."""
    from repro.trust.reputation import TrustSpec

    params = _params_multi()
    fs, ss, _, _ = _flat_vs_stream(
        params, attack="sign_flip", rule="rep_trimmed_mean", sparse=True,
        trust=TrustSpec(echo=False), screen_chunk=4, flat_chunk=1 << 20)
    for fl, sl in zip(jax.tree_util.tree_leaves(fs.params),
                      jax.tree_util.tree_leaves(ss.params), strict=True):
        np.testing.assert_allclose(np.asarray(fl, np.float32),
                                   np.asarray(sl, np.float32),
                                   rtol=2e-5, atol=2e-5)


def test_forensics_streams_and_emits_block_stream():
    from repro.obs.trace import BLOCK_TRIM_STREAM, TraceSpec

    params = _params_multi()
    grad_fn, targets = _task(params)
    cfg = BridgeConfig(topology=TOPO, rule="trimmed_mean", num_byzantine=B,
                       attack="sign_flip", lr=0.05, screen_chunk=4,
                       trace=TraceSpec())
    tr = StreamBridgeTrainer(cfg, grad_fn)
    state, metrics = _run(tr, params, targets, steps=2)
    nb = tr.spec.num_blocks
    assert metrics[BLOCK_TRIM_STREAM].shape == (nb,)
    assert "obs_trim_frac" in metrics
    # forensics stays bit-inert for the trajectory, chunked or not
    cfg_off = BridgeConfig(topology=TOPO, rule="trimmed_mean", num_byzantine=B,
                           attack="sign_flip", lr=0.05, screen_chunk=4)
    state_off, _ = _run(StreamBridgeTrainer(cfg_off, grad_fn), params, targets,
                        steps=2)
    assert _bitwise(state.params, state_off.params)
    # flat forensics would refuse to stream at this d/chunk; streaming's
    # per-block decide path is exactly what lifts the restriction
    with pytest.raises(ValueError, match="forensics cannot stream"):
        screening.check_decide_streams(("trimmed_mean",), 23, 4)


def test_trust_rejects_echo_on_network_path():
    from repro.trust.reputation import TrustSpec

    grad_fn, _ = _task(_params_single())
    cfg = BridgeConfig(topology=TOPO, rule="rep_trimmed_mean", num_byzantine=B,
                       attack="sign_flip", trust=TrustSpec(echo=True))
    with pytest.raises(ValueError, match="echo"):
        StreamBridgeTrainer(cfg, grad_fn, channel=StreamChannelConfig())


def test_streaming_rejects_adversaries():
    grad_fn, _ = _task(_params_single())
    cfg = BridgeConfig(topology=TOPO, rule="trimmed_mean", num_byzantine=B,
                       attack="none", adversary="ipm")
    with pytest.raises(NotImplementedError):
        StreamBridgeTrainer(cfg, grad_fn)


# ---------------------------------------------------------------------------
# Network path (per-block mailbox)
# ---------------------------------------------------------------------------


def test_network_ideal_channel_matches_broadcast():
    params = _params_multi()
    grad_fn, targets = _task(params)
    cfg = BridgeConfig(topology=TOPO, rule="trimmed_mean", num_byzantine=B,
                       attack="sign_flip", lr=0.05, screen_chunk=4)
    sync, _ = _run(StreamBridgeTrainer(cfg, grad_fn), params, targets)
    net, nm = _run(StreamBridgeTrainer(cfg, grad_fn,
                                       channel=StreamChannelConfig(drop_prob=0.0)),
                   params, targets)
    assert _bitwise(sync.params, net.params)
    assert float(nm["delivered_frac"]) == 1.0
    assert float(nm["screened_frac"]) == 1.0


def test_network_drop_channel_trains_and_reports():
    params = _params_multi()
    grad_fn, targets = _task(params)
    cfg = BridgeConfig(topology=TOPO, rule="trimmed_mean", num_byzantine=B,
                       attack="sign_flip", lr=0.05, screen_chunk=4)
    ch = StreamChannelConfig(drop_prob=0.4, staleness_bound=2)
    state, m = _run(StreamBridgeTrainer(cfg, grad_fn, channel=ch),
                    params, targets, steps=6)
    assert np.isfinite(float(m["loss"]))
    assert 0.0 < float(m["delivered_frac"]) < 1.0
    assert float(m["mean_staleness"]) >= 0.0
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


def test_network_mailbox_is_per_leaf():
    from repro.net.mailbox import BlockMailboxState

    params = _params_multi()
    grad_fn, targets = _task(params)
    cfg = BridgeConfig(topology=TOPO, rule="trimmed_mean", num_byzantine=B,
                       attack="sign_flip", lr=0.05, screen_chunk=4)
    tr = StreamBridgeTrainer(cfg, grad_fn, channel=StreamChannelConfig())
    state = tr.init(params, seed=0)
    assert isinstance(state.net, BlockMailboxState)
    sizes = tuple(v.shape[-1] for v in state.net.values)
    assert sizes == tuple(p.size for p in tr.spec.leaves)
    assert all(v.shape[:2] == (M, tr.neighbors.k) for v in state.net.values)


# ---------------------------------------------------------------------------
# HLO memory bound
# ---------------------------------------------------------------------------


def test_hlo_largest_tensor_below_flat_matrix():
    """At multi-leaf d, the streaming step's largest tensor is strictly below
    the flat path's [M, d] f32 matrix — the tensors that remain are leaf- or
    block-scale."""
    from repro.launch import hlo_analysis

    m, per_leaf, leaves = 6, 40_000, 4
    d = per_leaf * leaves
    keys = jax.random.split(jax.random.PRNGKey(0), leaves)
    p0 = {f"l{i}": jax.random.normal(k, (per_leaf,)) for i, k in enumerate(keys)}
    params = replicate(p0, m, perturb=0.1, key=jax.random.PRNGKey(1))
    grad_fn, targets = _task(params)
    cfg = BridgeConfig(topology=erdos_renyi(m, 1.0, 1, seed=0),
                       rule="trimmed_mean", num_byzantine=1,
                       attack="sign_flip", lr=0.05, screen_chunk=8192,
                       sparse=True)
    tr = StreamBridgeTrainer(cfg, grad_fn)
    state = tr.init(params, seed=0)
    text = (jax.jit(tr._raw_step)
            .lower(tr._cell, state, targets).compile().as_text())
    largest = hlo_analysis.largest_tensor_bytes(text)
    flat_bytes = m * d * 4
    assert largest < flat_bytes, (largest, flat_bytes)
    # and the bound is leaf/block-scale: well under half the flat matrix
    assert largest <= max(m * per_leaf * 4, m * tr.neighbors.k * 8192 * 4) * 2


# ---------------------------------------------------------------------------
# Checkpointing mid-run: comm/trust carries survive save/restore
# ---------------------------------------------------------------------------


def test_checkpoint_restores_stream_carries_bitwise(tmp_path):
    """Save the FULL streaming state (params + per-leaf EF residuals + trust
    reputation + PRNG key) after 3 ticks, restore into a fresh-init template,
    run 3 more — bitwise equal to the uninterrupted 6-tick run.  This is the
    contract `train_llm.py --resume` relies on."""
    from repro import checkpoint
    from repro.trust.reputation import TrustSpec

    params = _params_single()
    grad_fn, targets = _task(params)
    cfg = BridgeConfig(topology=TOPO, rule="rep_trimmed_mean", num_byzantine=B,
                       attack="sign_flip", codec="int8", sparse=True, lr=0.05,
                       trust=TrustSpec(echo=False))
    tr = StreamBridgeTrainer(cfg, grad_fn)

    full = tr.init(params, seed=0)
    for _ in range(6):
        full, _ = tr.step(full, targets)

    state = tr.init(params, seed=0)
    for _ in range(3):
        state, _ = tr.step(state, targets)
    assert state.comm is not None and state.trust is not None
    checkpoint.save(str(tmp_path), 3, state)

    template = StreamBridgeTrainer(cfg, grad_fn).init(params, seed=0)
    resumed, step = checkpoint.restore(str(tmp_path), template)
    assert step == 3
    assert _bitwise(resumed, state)  # carries round-trip exactly
    for _ in range(3):
        resumed, _ = tr.step(resumed, targets)
    assert _bitwise(full.params, resumed.params)
    assert _bitwise(full.comm, resumed.comm)
    assert _bitwise(full.trust, resumed.trust)


# ---------------------------------------------------------------------------
# stack_flatten mixed-dtype regression (satellite)
# ---------------------------------------------------------------------------


def test_stack_flatten_mixed_dtype_roundtrip():
    params = {
        "a": jnp.ones((M, 3), jnp.bfloat16) * 1.5,
        "b": jnp.full((M, 2), 0.1, jnp.float32),
        "c": jnp.ones((M,), jnp.float16),
    }
    flat, unflatten = stack_flatten(params)
    assert flat.dtype == jnp.float32 and flat.shape == (M, 6)
    back = unflatten(flat)
    for k in params:
        assert back[k].dtype == params[k].dtype, k
        assert bool(jnp.all(back[k] == params[k])), k
