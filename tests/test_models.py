"""Per-architecture smoke tests (reduced configs) + layer-level checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build, param_count
from repro.models import layers as L

B, S = 2, 64
KEY = jax.random.PRNGKey(0)


def _batch(cfg, rng):
    if cfg.family == "encdec":
        return {
            "audio_embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 33)), jnp.int32),
        }
    if cfg.family == "vlm":
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32),
            "image_embeds": jnp.asarray(rng.normal(size=(B, 16, cfg.d_model)), jnp.float32),
        }
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)}


@pytest.mark.slow
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch, rng):
    """Reduced variant: one forward/backward step, finite loss and grads."""
    cfg = get_config(arch).reduced()
    api = build(cfg)
    params = api.init_params(KEY, cfg)
    loss, grads = jax.jit(api.grad_fn())(params, _batch(cfg, rng))
    assert jnp.isfinite(loss), arch
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(grads))
    assert jnp.isfinite(gn) and float(gn) > 0, arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_decode_step(arch, rng):
    cfg = get_config(arch).reduced()
    api = build(cfg)
    params = api.init_params(KEY, cfg)
    cache = api.init_cache(cfg, B, 32)
    if cfg.family == "encdec":
        ae = jnp.asarray(rng.normal(size=(B, 32, cfg.d_model)), jnp.float32)
        cache = api.extra["prefill_cache"](params, cache, ae, cfg)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    step = jax.jit(lambda p, c, t: api.decode_step(p, c, t, cfg))
    logits, cache = step(params, cache, tok)
    logits, cache = step(params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab_size), arch
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-4b", "gemma3-12b", "rwkv6-3b", "zamba2-1.2b"])
def test_prefill_decode_consistency(arch, rng):
    """Chunked training-time recurrences must equal step-by-step decode."""
    cfg = get_config(arch).reduced()
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    T = 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    from repro.models import dense, hybrid, ssm

    fwd = {"dense": dense.forward, "rwkv": ssm.forward, "hybrid": hybrid.forward}[cfg.family]
    full = fwd(params, toks, cfg)
    cache = api.init_cache(cfg, B, T)
    outs = []
    step = jax.jit(lambda p, c, t: api.decode_step(p, c, t, cfg))
    for t in range(T):
        lg, cache = step(params, cache, toks[:, t : t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-9
    assert float(jnp.max(jnp.abs(full - dec))) / scale < 2e-4, arch


@pytest.mark.slow
def test_moe_prefill_decode_consistency(rng):
    """MoE: with generous capacity (no drops) decode must match prefill."""
    cfg = dataclasses.replace(get_config("deepseek-v2-236b").reduced(), capacity_factor=8.0)
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    T = 10
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    from repro.models import moe

    full, _ = moe.forward(params, toks, cfg)
    cache = api.init_cache(cfg, B, T)
    outs = []
    for t in range(T):
        lg, cache = api.decode_step(params, cache, toks[:, t : t + 1], cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-9
    assert float(jnp.max(jnp.abs(full - dec))) / scale < 2e-4


def test_chunked_attention_vs_naive(rng):
    b, s, h, dh = 2, 48, 4, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, 2, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, 2, dh)), jnp.float32)
    out = L.chunked_attention(q, k, v, causal=True, kv_chunk=16)
    # naive reference
    kk = jnp.repeat(k, 2, axis=2)
    vv = jnp.repeat(v, 2, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q / jnp.sqrt(dh), kk)
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, None], sc, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_sliding_window_equals_full_when_wide(rng):
    b, s, h, dh = 1, 64, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    full = L.chunked_attention(q, k, v, causal=True, kv_chunk=16)
    win = L.sliding_window_attention(q, k, v, window=s, q_chunk=16)
    np.testing.assert_allclose(np.asarray(win), np.asarray(full), rtol=2e-4, atol=2e-4)


def test_sliding_window_restricts(rng):
    """Tokens beyond the window must not influence the output."""
    b, s, h, dh, w = 1, 64, 2, 8, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    out1 = L.sliding_window_attention(q, k, v, window=w, q_chunk=16)
    k2 = k.at[:, :8].set(100.0)  # clobber tokens far outside the last window
    v2 = v.at[:, :8].set(-100.0)
    out2 = L.sliding_window_attention(q, k2, v2, window=w, q_chunk=16)
    np.testing.assert_allclose(np.asarray(out1[:, -16:]), np.asarray(out2[:, -16:]), rtol=1e-5)


def test_rope_rotation_property(rng):
    """RoPE: scores depend only on relative positions."""
    dh = 16
    q = jnp.asarray(rng.normal(size=(1, 1, 1, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, dh)), jnp.float32)
    def score(pq, pk):
        qr = L.apply_rope(q, jnp.asarray([pq]), 1e4)
        kr = L.apply_rope(k, jnp.asarray([pk]), 1e4)
        return float(jnp.sum(qr * kr))
    assert abs(score(3, 1) - score(10, 8)) < 1e-4
    assert abs(score(3, 1) - score(5, 1)) > 1e-5


def test_param_count_positive_and_scales():
    n_small = param_count(get_config("qwen3-4b").reduced())
    n_full = param_count(get_config("qwen3-4b"))
    assert 0 < n_small < n_full
    assert n_full > 3e9  # ~4B params
    assert param_count(get_config("deepseek-v3-671b")) > 5e11


def test_mtp_loss_differs(rng):
    cfg = get_config("deepseek-v3-671b").reduced()
    api = build(cfg)
    params = api.init_params(KEY, cfg)
    batch = _batch(cfg, rng)
    loss_mtp = api.train_loss(params, batch, cfg)
    cfg2 = dataclasses.replace(cfg, mtp=False)
    loss_plain = build(cfg2).train_loss(params, batch, cfg2)
    assert abs(float(loss_mtp) - float(loss_plain)) > 1e-6
