"""Docs checker: every markdown link must resolve, every documented CLI must
answer ``--help``.

    python tools/check_docs.py [--root .] [--no-help-smoke]

Two classes of doc rot this catches, both cheap to prevent and embarrassing
to ship:

* **Dead links.**  Inline links in ``README.md``, ``docs/*.md``, and the
  top-level ``*.md`` project files are extracted (code fences and inline
  code spans are stripped first — ``[M, K]`` is an array shape, not a link),
  and every relative target must exist on disk.  Fragments are checked too:
  ``docs/FILE.md#some-heading`` must match a real heading's GitHub-style
  anchor slug in that file.  External ``http(s)://`` / ``mailto:`` targets
  are *not* fetched — CI must not flake on someone else's server.
* **Stale CLI references.**  The entry points the docs tell people to run
  (``repro.launch.train``, ``repro.launch.sweep``, ``repro.obs.report``)
  are invoked with ``--help`` in a subprocess with ``PYTHONPATH=src``; a
  refactor that renames or breaks an entry point fails the docs job, not a
  user.

Stdlib only (no pip deps) so the CI job needs nothing but a checkout and a
Python. Exit status: 0 clean, 1 any problem; every problem is printed as
``file:line: message``.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

# the CLIs the docs instruct readers to run — keep in sync with README
HELP_SMOKE_MODULES = (
    "repro.launch.train",
    "repro.launch.sweep",
    "repro.obs.report",
)

_FENCE = re.compile(r"^(```|~~~)")
_INLINE_CODE = re.compile(r"`[^`]*`")
# [text](target) — target may carry a #fragment; images (![alt](...)) match
# too via the optional bang, and are checked the same way
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^(#{1,6})\s+(.*)$")


def doc_files(root: str) -> list[str]:
    """README + docs/*.md + the top-level project markdown files."""
    found = []
    for name in sorted(os.listdir(root)):
        if name.endswith(".md"):
            found.append(os.path.join(root, name))
    docs_dir = os.path.join(root, "docs")
    if os.path.isdir(docs_dir):
        for name in sorted(os.listdir(docs_dir)):
            if name.endswith(".md"):
                found.append(os.path.join(docs_dir, name))
    return found


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: drop code ticks/punctuation, spaces to hyphens."""
    text = heading.strip().lower().replace("`", "")
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_slugs(path: str) -> set[str]:
    slugs: dict[str, int] = {}
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for line in f:
            if _FENCE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = _HEADING.match(line)
            if not m:
                continue
            slug = github_slug(m.group(2))
            # repeated headings get -1, -2, ... suffixes on GitHub
            n = slugs.get(slug, -1) + 1
            slugs[slug] = n
            if n:
                slugs[f"{slug}-{n}"] = 0
    return set(slugs)


def iter_links(path: str):
    """Yield (lineno, target) for every inline link outside code."""
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if _FENCE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            scrubbed = _INLINE_CODE.sub("", line)
            for m in _LINK.finditer(scrubbed):
                yield lineno, m.group(1)


def check_links(root: str) -> list[str]:
    problems = []
    for path in doc_files(root):
        rel = os.path.relpath(path, root)
        for lineno, target in iter_links(path):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            base, _, frag = target.partition("#")
            dest = path if not base else os.path.normpath(
                os.path.join(os.path.dirname(path), base))
            if base and not os.path.exists(dest):
                problems.append(f"{rel}:{lineno}: broken link -> {target}")
                continue
            if frag and dest.endswith(".md"):
                if frag not in heading_slugs(dest):
                    problems.append(
                        f"{rel}:{lineno}: missing anchor -> {target}")
    return problems


def check_help(root: str) -> list[str]:
    problems = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), env.get("PYTHONPATH")) if p)
    env.setdefault("JAX_PLATFORMS", "cpu")
    for mod in HELP_SMOKE_MODULES:
        proc = subprocess.run(
            [sys.executable, "-m", mod, "--help"],
            cwd=root, env=env, capture_output=True, text=True, timeout=300)
        if proc.returncode != 0:
            tail = (proc.stderr or proc.stdout).strip().splitlines()[-1:]
            problems.append(
                f"{mod}: --help exited {proc.returncode}"
                + (f" ({tail[0]})" if tail else ""))
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    ap.add_argument("--no-help-smoke", action="store_true",
                    help="only check markdown links (fast, no subprocesses)")
    args = ap.parse_args(argv)

    problems = check_links(args.root)
    if not args.no_help_smoke:
        problems += check_help(args.root)
    for p in problems:
        print(p)
    n_docs = len(doc_files(args.root))
    if problems:
        print(f"docs check FAILED: {len(problems)} problem(s) "
              f"across {n_docs} markdown file(s)")
        return 1
    print(f"docs check ok: {n_docs} markdown file(s), links resolve"
          + ("" if args.no_help_smoke else
             f", {len(HELP_SMOKE_MODULES)} CLIs answer --help"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
