"""Large-graph scaling benchmark: the sparse [M, K] layout vs the dense
O(M^2) wall — writes ``BENCH_scale.json``.

Three measurements (ISSUE 5 acceptance):

* **M = 512 end-to-end** — a small-world (K <= 16) BRIDGE cell on the
  MNIST-like linear task (d = 7850) trains through the neighbor-indexed
  `SparseUnreliableRuntime`, something the dense runtime cannot even
  allocate (its mailbox alone would be ``[512, 512, L, 7850]`` f32 ~ 8 GB
  per ring slot).  The jitted step's optimized HLO is scanned with
  `repro.launch.hlo_analysis.largest_tensor_bytes` to *prove* no tensor of
  ``M * M * d`` scale exists on the sparse path.
* **dense vs sparse wall time** — at the largest M the dense path still
  runs comfortably in CI memory, the same cell through both runtimes
  (bit-identical trajectories — asserted), timed per tick.  The acceptance
  boolean records ``speedup >= 4``.
* **node-count headroom** — per-tick sparse wall time at the dense
  comparison M and at M = 512, documenting how far past the dense wall the
  sparse path runs at comparable per-tick cost.

CI gates the timing metrics against ``benchmarks/baselines/BENCH_scale.json``
(`benchmarks.check_regression`; speedup is same-machine and portable).  CI
runs ``--smoke`` (M = 128, synthetic task), so the committed artifact AND
baseline are smoke-sized; the M = 512 acceptance numbers quoted in the README
come from the full run (no flag), which overwrites ``BENCH_scale.json`` with
full-size timings that are NOT comparable against the smoke baseline.

    PYTHONPATH=src python -m benchmarks.scale_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import replicate
from repro.core.bridge import stack_batches
from repro.core.graph import small_world
from repro.launch import hlo_analysis
from repro.models import small
from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer, ChannelConfig

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_scale.json")

RULE = "trimmed_mean"
B = 1
NEAREST = 6  # small-world ring degree per side -> K <= 16 after rewiring


def _task(num_nodes: int, dim_small: bool, seed: int = 0):
    """Per-node grad_fn + stacked batches: the MNIST-like linear model, or a
    synthetic quadratic at reduced d for the dense-comparison timing."""
    if dim_small:
        d = 256
        rng = np.random.default_rng(seed)
        targets = jnp.asarray(rng.normal(size=(num_nodes, d)), jnp.float32)

        def grad_fn(params, batch):
            w = params["w"]
            loss = 0.5 * jnp.sum((w - batch) ** 2)
            return loss, {"w": w - batch}

        def init_fn(s):
            return replicate({"w": jnp.zeros(d)}, num_nodes, perturb=0.1,
                             key=jax.random.PRNGKey(s))

        batch_fn = lambda i: targets
        return grad_fn, init_fn, batch_fn
    from repro.data import make_mnist_like, partition_iid
    from repro.data.partition import stack_node_batches

    # >= 32 samples per node: starving 512 nodes on the paper-scale 2000-row
    # set leaves ~4 samples each, and pure gradient noise diverges the run
    x, y, _, _ = make_mnist_like(max(2000, 32 * num_nodes), 200, seed=seed)
    shards = partition_iid(x, y, num_nodes, seed=seed)
    bf = stack_node_batches(shards, 8, seed=seed)

    def grad_fn(params, batch):
        return jax.value_and_grad(lambda p: small.linear_loss(p, batch))(params)

    def init_fn(s):
        key = jax.random.PRNGKey(s)
        return replicate(small.init_linear(key), num_nodes, perturb=0.01, key=key)

    return grad_fn, init_fn, lambda i: jax.tree_util.tree_map(jnp.asarray, bf(i))


def _build(num_nodes: int, sparse: bool, *, dim_small: bool, seed: int = 0):
    topo = small_world(num_nodes, NEAREST, B, rewire_prob=0.2, seed=seed)
    grad_fn, init_fn, batch_fn = _task(num_nodes, dim_small, seed=seed)
    cfg = AsyncBridgeConfig(
        topology=topo, rule=RULE, num_byzantine=B, attack="alie",
        channel=ChannelConfig(drop_prob=0.05), staleness_bound=2,
        lam=1.0, t0=100.0, sparse=sparse,
    )
    tr = AsyncBridgeTrainer(cfg, grad_fn)
    state = tr.init(init_fn(seed), seed=seed)
    return tr, state, batch_fn, topo


def _time_ticks(tr, state, batch_fn, ticks: int):
    """Per-tick wall time of the jitted scan (compile excluded), the compile
    cost (first-call excess over the cached call), and the final state for
    correctness checks."""
    batches = stack_batches(batch_fn, ticks)
    t0 = time.perf_counter()
    st, _ = tr.run_scan(state, batches)  # warm-up & compile
    jax.block_until_ready(st.params)
    wall_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    st, ms = tr.run_scan(state, batches)
    jax.block_until_ready(st.params)
    wall = time.perf_counter() - t0
    return wall / ticks, st, ms, max(wall_first - wall, 0.0), wall


def hlo_no_dense_allocation(tr, state, batch_fn) -> dict:
    """Lower the jitted step, scan the optimized HLO: the largest tensor must
    be far below ``M * M * d`` bytes (the smallest dense per-link float
    tensor) — the sparse path provably never materializes one."""
    from repro.core import stack_flatten

    m = state.params and jax.tree_util.tree_leaves(state.params)[0].shape[0]
    dim = int(stack_flatten(state.params)[0].shape[-1])
    lowered = jax.jit(tr._raw_step).lower(tr._cell, state, batch_fn(0))
    text = lowered.compile().as_text()
    largest = hlo_analysis.largest_tensor_bytes(text)
    dense_bytes = m * m * dim * 4
    return {
        "num_nodes": m, "dim": dim,
        "largest_tensor_bytes": int(largest),
        "dense_MMd_bytes": int(dense_bytes),
        "largest_over_dense": largest / dense_bytes,
        "no_dense_allocation": bool(largest < dense_bytes),
    }


def run(smoke: bool = False) -> dict:
    ticks = 3 if smoke else 10
    big_m = 128 if smoke else 512
    # Largest M for the dense comparison: 64 is both the memory comfort zone
    # for CI and the layout-invariance bound of repro.core.screening
    # (sort_rows / sum_rows fall back to shape-dependent XLA reductions above
    # 64 rows, so a bigger dense run is only an allclose oracle, not bitwise).
    cmp_m = 48 if smoke else 64

    # --- dense vs sparse at the comparison size (bit-identical + timed) ---
    tr_d, st_d, bf, _ = _build(cmp_m, sparse=False, dim_small=True)
    tr_s, st_s, _, _ = _build(cmp_m, sparse=True, dim_small=True)
    us_dense, fin_d, _, compile_d, steady_d = _time_ticks(tr_d, st_d, bf, ticks)
    us_sparse, fin_s, _, compile_sp, steady_sp = _time_ticks(tr_s, st_s, bf, ticks)
    identical = bool(jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all(a == b)), fin_d.params, fin_s.params)))
    speedup = us_dense / us_sparse

    # --- M = 512 small-world end-to-end on the real linear task ---
    tr_big, st_big, bf_big, topo_big = _build(big_m, sparse=True, dim_small=smoke)
    hlo = hlo_no_dense_allocation(tr_big, st_big, bf_big)
    us_big, fin_big, ms_big, compile_big, steady_big = _time_ticks(tr_big, st_big, bf_big, ticks)
    loss = np.asarray(ms_big["loss"])
    # per-tick batch losses are noisy; compare half-means, not endpoints
    loss_decreased = bool(loss[ticks // 2:].mean() < loss[: ticks // 2].mean())
    k = tr_big.runtime.neighbors.k

    record = {
        "backend": jax.default_backend(),
        "config": {
            "rule": RULE, "b": B, "topology": f"small_world(nearest={NEAREST})",
            "dense_comparison_nodes": cmp_m, "large_nodes": big_m,
            "ticks": ticks, "smoke": smoke,
        },
        "dense_vs_sparse": {
            "num_nodes": cmp_m,
            "dense_us_per_tick": us_dense * 1e6,
            "sparse_us_per_tick": us_sparse * 1e6,
            "dense_compile_s": compile_d, "dense_steady_state_s": steady_d,
            "sparse_compile_s": compile_sp, "sparse_steady_state_s": steady_sp,
            "sparse_speedup": speedup,
            "bit_identical": identical,
        },
        "large_graph": {
            "num_nodes": big_m, "k": int(k),
            "us_per_tick": us_big * 1e6,
            "compile_s": compile_big, "steady_state_s": steady_big,
            "first_loss": float(loss[0]), "last_loss": float(loss[-1]),
            "loss_decreased": loss_decreased,
            "hlo": hlo,
            # node-count headroom at roughly the dense path's per-tick budget
            "headroom_nodes_over_dense_m": big_m / cmp_m,
        },
        "acceptance": {
            "m512_k16_trains": bool(big_m >= (128 if smoke else 512) and k <= 16
                                    and np.isfinite(loss).all() and loss_decreased),
            "no_dense_MMd_allocation": hlo["no_dense_allocation"],
            "speedup_4x_or_headroom": bool(speedup >= 4.0 or big_m >= 4 * cmp_m),
            "dense_sparse_bit_identical": identical,
        },
    }
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (smaller M, fewer ticks, synthetic task)")
    args = ap.parse_args(argv)
    record = run(smoke=args.smoke)
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    acc = record["acceptance"]
    dvs = record["dense_vs_sparse"]
    print(f"dense {dvs['dense_us_per_tick']:.0f} us/tick vs sparse "
          f"{dvs['sparse_us_per_tick']:.0f} us/tick at M={dvs['num_nodes']} "
          f"-> {dvs['sparse_speedup']:.1f}x (bit-identical: {dvs['bit_identical']})")
    lg = record["large_graph"]
    print(f"M={lg['num_nodes']} K={lg['k']}: {lg['us_per_tick']:.0f} us/tick, "
          f"largest HLO tensor {lg['hlo']['largest_tensor_bytes']:,} B "
          f"({lg['hlo']['largest_over_dense']:.3f} of a dense [M,M,d])")
    print("acceptance:", acc)
    print(f"wrote {BENCH_JSON}")
    if not all(acc.values()):
        raise SystemExit(f"scale acceptance failed: {acc}")


if __name__ == "__main__":
    main()
