"""Network-runtime benchmarks: the ``--scenario async_lossy`` axis.

Times the `repro.net` scan-over-ticks hot path (mailbox ring + channel
sampling + asynchronous screening + gradient step, all inside one jitted
``lax.scan``) across network conditions, on the same MNIST-like linear task
the paper-figure benchmarks use.  Emits CSV rows for the `benchmarks.run`
harness and dumps ``BENCH_net.json`` so later PRs can track the runtime's
perf trajectory.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_accuracy, get_data, make_grad_fn
from repro.core import erdos_renyi, replicate
from repro.data import partition_iid
from repro.data.partition import stack_node_batches
from repro.models import small

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "BENCH_net.json")


def async_lossy_scenarios(num_nodes: int = 20, ticks: int = 120, *,
                          rule: str = "trimmed_mean", attack: str = "alie",
                          num_byzantine: int = 2, seed: int = 0):
    """rule x attack fixed, network-condition axis swept (the canonical
    `repro.net.scenarios` registry); returns CSV rows and writes
    BENCH_net.json."""
    from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer
    from repro.net.dynamic import scenario_schedule
    from repro.net.scenarios import NET_SCENARIOS

    x, y, xt, yt = get_data()
    shards = partition_iid(x, y, num_nodes, seed=seed)
    batch_fn = stack_node_batches(shards, 32, seed=seed)
    topo = erdos_renyi(num_nodes, 0.5, num_byzantine, seed=seed)
    key = jax.random.PRNGKey(seed)
    params = replicate(small.init_linear(key), num_nodes, perturb=0.01, key=key)
    grad_fn = make_grad_fn("linear")

    batches = [batch_fn(i) for i in range(ticks)]
    stacked = tuple(jnp.asarray(np.stack([b[i] for b in batches])) for i in range(2))

    rows, record = [], {}
    for name, spec in NET_SCENARIOS.items():
        cfg = AsyncBridgeConfig(
            topology=topo, rule=rule, num_byzantine=num_byzantine, attack=attack,
            lam=1.0, t0=30.0, channel=spec.channel,
            staleness_bound=spec.staleness_bound,
            schedule=scenario_schedule(spec.schedule_kind, topo, ticks, seed=seed,
                                       churn_prob=spec.churn_prob),
        )
        tr = AsyncBridgeTrainer(cfg, grad_fn)
        state = tr.init(params)
        # compile once (timed: first wall minus steady wall = compile cost),
        # then time the steady-state scan — only the latter is CI-gated
        t0 = time.perf_counter()
        st, ms = tr.run_scan(state, stacked)
        jax.block_until_ready(st.params)
        wall_first = time.perf_counter() - t0
        t0 = time.perf_counter()
        st, ms = tr.run_scan(state, stacked)
        jax.block_until_ready(st.params)
        wall_steady = time.perf_counter() - t0
        us_per_tick = wall_steady / ticks * 1e6
        acc = eval_accuracy("linear", st.params, tr.honest_mask,
                            jnp.asarray(xt), jnp.asarray(yt))
        record[name] = {
            "us_per_tick": us_per_tick,
            "compile_s": max(wall_first - wall_steady, 0.0),
            "steady_state_s": wall_steady,
            "accuracy": acc,
            "final_loss": float(ms["loss"][-1]),
            "delivered_frac": float(np.mean(np.asarray(ms["delivered_frac"]))),
            "mean_staleness": float(np.mean(np.asarray(ms["mean_staleness"]))),
            "rule": rule, "attack": attack, "num_nodes": num_nodes,
            "ticks": ticks,
        }
        rows.append((f"net/{name}", us_per_tick,
                     f"acc={acc:.4f};delivered={record[name]['delivered_frac']:.2f}"))
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    return rows
