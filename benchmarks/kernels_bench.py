"""Screening-kernel throughput: Pallas (interpret on CPU; compiled on TPU)
vs the pure-jnp oracle, swept over model dimension d.

Emits ``BENCH_kernels.json`` for the CI regression gate (the jnp-oracle
timings are the gated hot path — they are what `repro.core.screening`
actually runs on CPU; the interpret-mode Pallas rows are recorded for
context but deliberately keyed so the gate ignores them, since interpreter
speed is not a property of the kernel).

    PYTHONPATH=src python -m benchmarks.kernels_bench
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_kernels.json")


def _time(fn, *args, reps=3):
    """(steady us/call, compile seconds): the warm-up call's excess over a
    cached call is the trace+compile cost."""
    t0 = time.perf_counter()
    fn(*args).block_until_ready()
    first_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args).block_until_ready()
    us = (time.perf_counter() - t0) / reps * 1e6
    return us, max(first_s - us / 1e6, 0.0)


def kernel_throughput(n=25, b=2, dims=(4096, 65536, 1048576)):
    rows = []
    record = {}
    rng = np.random.default_rng(0)
    compile_total = steady_total = 0.0
    for d in dims:
        vals = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        mask = jnp.ones((n,), bool)
        sv = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        us_ref, c_ref = _time(jax.jit(lambda v, m, s: ref.trimmed_mean_ref(v, m, s, b)), vals, mask, sv)
        mbs = n * d * 4 / (us_ref / 1e6) / 1e6
        rows.append((f"kernel/trimmed_mean_ref/d{d}", us_ref, f"MB_s={mbs:.0f}"))
        record[f"trimmed_mean_ref_d{d}"] = {"us_per_call": us_ref, "mb_per_s": mbs}
        us_med, c_med = _time(jax.jit(lambda v, m: ref.median_ref(v, m)), vals, mask)
        rows.append((f"kernel/median_ref/d{d}", us_med, ""))
        record[f"median_ref_d{d}"] = {"us_per_call": us_med}
        compile_total += c_ref + c_med
        steady_total += (us_ref + us_med) / 1e6
        if d <= 65536:  # interpret mode is python-speed; keep it bounded
            us_pl, _ = _time(
                lambda v=vals, m=mask, s=sv: ops.trimmed_mean(v, m, s, b, block_d=512),
                reps=1,
            )
            rows.append((f"kernel/trimmed_mean_pallas_interp/d{d}", us_pl,
                         "interpret=True (TPU target)"))
            # interpreter speed is environment, not kernel, quality: keyed
            # so the regression gate's metric discovery skips it
            record[f"trimmed_mean_pallas_interp_d{d}"] = {"interp_us": us_pl}
    with open(BENCH_JSON, "w") as f:
        json.dump({"kernels": record,
                   "config": {"n": n, "b": b, "dims": list(dims),
                              "backend": jax.default_backend()},
                   # total across the gated jnp-oracle calls (interpret-mode
                   # rows excluded); compile_s is never gated
                   "compile_s": compile_total,
                   "steady_state_s": steady_total},
                  f, indent=2, sort_keys=True)
    return rows


def main(argv=None):
    del argv
    print("name,us_per_call,derived")
    for name, us, derived in kernel_throughput():
        print(f"{name},{us:.1f},{derived}", flush=True)
    print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
