"""Screening-kernel throughput: Pallas (interpret on CPU; compiled on TPU)
vs the pure-jnp oracle, swept over model dimension d."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_throughput(n=25, b=2, dims=(4096, 65536, 1048576)):
    rows = []
    rng = np.random.default_rng(0)
    for d in dims:
        vals = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        mask = jnp.ones((n,), bool)
        sv = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        us_ref = _time(jax.jit(lambda v, m, s: ref.trimmed_mean_ref(v, m, s, b)), vals, mask, sv)
        mbs = n * d * 4 / (us_ref / 1e6) / 1e6
        rows.append((f"kernel/trimmed_mean_ref/d{d}", us_ref, f"MB_s={mbs:.0f}"))
        if d <= 65536:  # interpret mode is python-speed; keep it bounded
            us_pl = _time(
                lambda v=vals, m=mask, s=sv: ops.trimmed_mean(v, m, s, b, block_d=512),
                reps=1,
            )
            rows.append((f"kernel/trimmed_mean_pallas_interp/d{d}", us_pl,
                         "interpret=True (TPU target)"))
    return rows
