"""Observability-layer benchmark: trace overhead + forensics quality —
writes ``BENCH_obs.json`` and a CI-uploadable traced-run artifact dir.

Two measurements (ISSUE 6 acceptance):

* **aggregate-mode trace overhead** — a sparse small-world BRIDGE cell
  (M = 512, K <= 16 full; CI ``--smoke`` runs M = 128) through the
  neighbor-indexed runtime twice: untraced vs ``TraceSpec(forensics=True)``
  compiled into the scan, on TWO workloads.  The ``paper_scale`` cell is the
  replication workload itself (the MNIST-like linear task, d = 7850 — the
  same M = 512 configuration scale_bench's acceptance runs) and carries the
  < 10% acceptance budget.  The ``screen_stress`` cell is a synthetic d = 64
  quadratic where screening is essentially the whole tick — the worst case
  for instrumenting the screen — reported and loosely gated (0.5) purely to
  catch pathological regressions (losing the sort-materialization anchor
  shows up as +100..400% here).  Steady-state walls only (min over ``reps``
  cached runs; compile split out per the bench-timing convention), asserting
  the traced trajectory is BIT-IDENTICAL to the untraced one on both cells.
* **forensics are actionable** — a traced M = 64 grid (rule x attack cells,
  known Byzantine mask) written out as the real artifact set: ``events.jsonl``
  (`repro.obs.events.EventLog`), ``obs_summary.json`` (per-cell
  `repro.obs.trace.summarize`), and the rendered ``report.txt``.  The bench
  asserts the per-edge trim-frequency counters rank true Byzantine in-edges
  above honest edges (Mann-Whitney AUC) for every screening rule traced.

CI gates the timing metrics against ``benchmarks/baselines/BENCH_obs.json``
(`benchmarks.check_regression`; the baseline is smoke-sized, matching the CI
invocation — see scale_bench for the convention) and uploads the artifact
dir, so a traced run's event log and forensics report are inspectable on
every PR.

    PYTHONPATH=src python -m benchmarks.obs_bench [--smoke] [--out DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import erdos_renyi, replicate
from repro.core.bridge import stack_batches
from repro.core.graph import small_world
from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer, ChannelConfig
from repro.obs import EventLog, TraceSpec, read_events
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.sim import ExperimentGrid, GridEngine

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_obs.json")

RULE = "trimmed_mean"
B = 2
NEAREST = 6  # small-world ring degree per side -> K <= 16 after rewiring
DIM = 64


def _build(num_nodes: int, trace: TraceSpec | None, seed: int = 0,
           paper: bool = False):
    """One sparse small-world BRIDGE cell.  ``paper=False``: a synthetic
    quadratic at d = 64, where the screening/obs work dominates — the worst
    case for the overhead ratio.  ``paper=True``: the replication workload
    (scale_bench's MNIST-like linear task, d = 7850)."""
    topo = small_world(num_nodes, NEAREST, B, rewire_prob=0.2, seed=seed)
    if paper:
        from benchmarks.scale_bench import _task

        grad_fn, init_fn, batch_fn = _task(num_nodes, dim_small=False, seed=seed)
        params = init_fn(seed)
    else:
        rng = np.random.default_rng(seed)
        targets = jnp.asarray(rng.normal(size=(num_nodes, DIM)), jnp.float32)

        def grad_fn(params, batch):
            w = params["w"]
            loss = 0.5 * jnp.sum((w - batch) ** 2)
            return loss, {"w": w - batch}

        batch_fn = lambda i: targets
        params = replicate({"w": jnp.zeros(DIM)}, num_nodes, perturb=0.1,
                           key=jax.random.PRNGKey(seed))
    cfg = AsyncBridgeConfig(
        topology=topo, rule=RULE, num_byzantine=B, attack="alie",
        channel=ChannelConfig(drop_prob=0.05), staleness_bound=2,
        lam=1.0, t0=100.0, sparse=True, trace=trace,
    )
    tr = AsyncBridgeTrainer(cfg, grad_fn)
    state = tr.init(params, seed=seed)
    return tr, state, batch_fn


def _steady_wall(tr, state, batches, ticks: int, reps: int):
    """(min steady wall over reps, compile_s, final state): first call pays
    trace + compile; the min over cached re-runs is the honest scan cost."""
    t0 = time.perf_counter()
    st, _ = tr.run_scan(state, batches)
    jax.block_until_ready(st.params)
    wall_first = time.perf_counter() - t0
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        st, _ = tr.run_scan(state, batches)
        jax.block_until_ready(st.params)
        walls.append(time.perf_counter() - t0)
    steady = min(walls)
    return steady, max(wall_first - steady, 0.0), st


def trace_overhead(num_nodes: int, ticks: int, reps: int, budget: float,
                   *, paper: bool = False, decide_stride: int = 4) -> dict:
    # aggregate-only: forensics counters, no reservoir.  decide_stride is the
    # production large-run config — the membership sweep samples every
    # stride-th coordinate; the forensics AUC below is measured under the
    # SAME spec, so the gate certifies the config whose overhead is quoted
    spec = TraceSpec(decide_stride=decide_stride)
    tr_off, st_off, bf = _build(num_nodes, None, paper=paper)
    tr_on, st_on, _ = _build(num_nodes, spec, paper=paper)
    # materialize the batch stack ONCE: stack_node_batches closures are
    # stateful (the rng advances per call), and the bit-identity check is
    # meaningless unless both runs scan the same draws
    batches = stack_batches(bf, ticks)
    steady_off, compile_off, fin_off = _steady_wall(tr_off, st_off, batches, ticks, reps)
    steady_on, compile_on, fin_on = _steady_wall(tr_on, st_on, batches, ticks, reps)
    identical = bool(jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all(a == b)), fin_off.params, fin_on.params)))
    overhead = steady_on / steady_off - 1.0

    # forensics from the SAME traced run: do the counters separate the known
    # Byzantine senders under an adaptive-style attack?
    senders = obs_trace.sender_grid(num_nodes, neighbors=tr_on.runtime.neighbors)
    summary = obs_trace.summarize(spec, fin_on.obs,
                                  byz_mask=np.asarray(tr_on.byz_mask), senders=senders)
    d = sum(leaf.size for leaf in jax.tree_util.tree_leaves(fin_on.params)) // num_nodes
    return {
        "num_nodes": num_nodes, "k": int(tr_on.runtime.neighbors.k),
        "dim": d, "ticks": ticks, "reps": reps,
        "decide_stride": decide_stride,
        "untraced_us_per_tick": steady_off / ticks * 1e6,
        "traced_us_per_tick": steady_on / ticks * 1e6,
        "untraced_steady_state_s": steady_off, "traced_steady_state_s": steady_on,
        "untraced_compile_s": compile_off, "traced_compile_s": compile_on,
        "overhead_frac": overhead, "overhead_budget": budget,
        "bit_identical": identical,
        "auc_byzantine_edges": summary["auc_byzantine_edges"],
        "survival": summary["survival"],
    }


def traced_grid_artifacts(out_dir: str, num_nodes: int = 64, ticks: int = 40,
                          seed: int = 0) -> dict:
    """The CI artifact set: a traced M=64 grid run leaving ``events.jsonl``
    + ``obs_summary.json`` + rendered ``report.txt`` in ``out_dir``."""
    os.makedirs(out_dir, exist_ok=True)
    topo = erdos_renyi(num_nodes, 0.2, B, seed=seed)
    rng = np.random.default_rng(seed)
    targets = jnp.asarray(rng.normal(size=(num_nodes, 8)), jnp.float32)

    def grad_fn(params, batch):
        w = params["w"]
        loss = 0.5 * jnp.sum((w - batch) ** 2)
        return loss, {"w": w - batch}

    def init_fn(s):
        return replicate({"w": jnp.zeros(8)}, num_nodes, perturb=0.1,
                         key=jax.random.PRNGKey(s))

    spec = TraceSpec(reservoir=4, stride=max(ticks // 4, 1))
    events_path = os.path.join(out_dir, "events.jsonl")
    grid = ExperimentGrid(topo, ("trimmed_mean", "median"), ("alie",), (B,),
                          (seed,), lam=1.0, t0=30.0)
    with EventLog(events_path) as ev:
        engine = GridEngine(grid, grad_fn, trace=spec, events=ev,
                            # two compiled chunks so grid.chunk events land
                            # in the artifact log CI uploads
                            )
        state = engine.init(init_fn)
        final, metrics = engine.run(state, stack_batches(lambda i: targets, ticks),
                                    chunk=1)
    senders = engine.sender_grid()
    cells = []
    for i, c in enumerate(engine.cells):
        obs_i = jax.tree_util.tree_map(lambda leaf: leaf[i], final.obs)
        cells.append({"tag": c.tag, "rule": c.rule,
                      **obs_trace.summarize(spec, obs_i,
                                            byz_mask=engine.byz_masks[i],
                                            senders=senders)})
    summary = {"meta": {"kind": "obs_bench", "num_nodes": num_nodes,
                        "ticks": ticks, "events": events_path},
               "cells": cells}
    summary_path = os.path.join(out_dir, "obs_summary.json")
    with open(summary_path, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
    report = obs_report.render(summary, read_events(events_path))
    report_path = os.path.join(out_dir, "report.txt")
    with open(report_path, "w") as f:
        f.write(report)
    return {
        "num_nodes": num_nodes, "ticks": ticks,
        "cells": [{"tag": c["tag"], "rule": c["rule"],
                   "auc_byzantine_edges": c["auc_byzantine_edges"],
                   "byz_trim_freq": c["survival"]["byz_trim_freq"],
                   "honest_trim_freq": c["survival"]["honest_trim_freq"]}
                  for c in cells],
        "events": len(read_events(events_path)),
        "artifacts": {"events": events_path, "summary": summary_path,
                      "report": report_path},
    }


def run(smoke: bool = False, out_dir: str | None = None) -> dict:
    if smoke:
        m = 128  # CI-sized; walls are noise-bound, budgets are loose
        stress = trace_overhead(m, ticks=10, reps=2, budget=0.5)
        paper = trace_overhead(m, ticks=3, reps=2, budget=0.25,
                               paper=True, decide_stride=16)
    else:
        m = 512
        stress = trace_overhead(m, ticks=20, reps=3, budget=0.5)
        # THE acceptance cell: < 10% on the M = 512 replication workload
        paper = trace_overhead(m, ticks=3, reps=2, budget=0.10,
                               paper=True, decide_stride=16)
    artifacts = traced_grid_artifacts(out_dir or os.path.join(_ROOT, "obs_run"))
    aucs = [c["auc_byzantine_edges"] for c in artifacts["cells"]]
    aucs.append(stress["auc_byzantine_edges"])
    record = {
        "backend": jax.default_backend(),
        "config": {"rule": RULE, "b": B, "smoke": smoke,
                   "topology": f"small_world(nearest={NEAREST})"},
        "overhead": {"paper_scale": paper, "screen_stress": stress},
        "forensics": artifacts,
        "acceptance": {
            "trace_bit_inert": bool(paper["bit_identical"]
                                    and stress["bit_identical"]),
            "overhead_within_budget": bool(
                paper["overhead_frac"] < paper["overhead_budget"]
                and stress["overhead_frac"] < stress["overhead_budget"]),
            "byzantine_edges_ranked": bool(
                all(a is not None and a >= 0.7 for a in aucs)),
        },
    }
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (M=128 overhead cell, looser budget)")
    ap.add_argument("--out", default=None, metavar="DIR",
                    help="traced-run artifact dir (default: ./obs_run)")
    args = ap.parse_args(argv)
    record = run(smoke=args.smoke, out_dir=args.out)
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    for name, ov in record["overhead"].items():
        print(f"{name} M={ov['num_nodes']} K={ov['k']} d={ov['dim']}: untraced "
              f"{ov['untraced_us_per_tick']:.0f} us/tick vs traced "
              f"{ov['traced_us_per_tick']:.0f} us/tick -> "
              f"{ov['overhead_frac'] * 100:+.1f}% (budget "
              f"{ov['overhead_budget'] * 100:.0f}%, bit-identical: {ov['bit_identical']})")
    for c in record["forensics"]["cells"]:
        print(f"  {c['tag']}: auc={c['auc_byzantine_edges']:.3f} "
              f"byz_trim={c['byz_trim_freq']:.3f} honest_trim={c['honest_trim_freq']:.3f}")
    print(f"artifacts -> {record['forensics']['artifacts']['report']}")
    print(f"wrote {BENCH_JSON}")
    acc = record["acceptance"]
    if not all(acc.values()):
        raise SystemExit(f"obs acceptance failed: {acc}")


if __name__ == "__main__":
    main()
