"""Observability-layer benchmark: trace overhead + live-telemetry overhead +
forensics quality — writes ``BENCH_obs.json`` and a CI-uploadable artifact dir.

Three measurements (ISSUE 6 + ISSUE 9 acceptance):

* **aggregate-mode trace overhead** — a sparse small-world BRIDGE cell
  (M = 512, K <= 16 full; CI ``--smoke`` runs M = 128) through the
  neighbor-indexed runtime twice: untraced vs ``TraceSpec(forensics=True)``
  compiled into the scan, on TWO workloads.  The ``paper_scale`` cell is the
  replication workload itself (the MNIST-like linear task, d = 7850 — the
  same M = 512 configuration scale_bench's acceptance runs) and carries the
  < 10% acceptance budget.  The ``screen_stress`` cell is a synthetic d = 64
  quadratic where screening is essentially the whole tick — the worst case
  for instrumenting the screen — reported and loosely gated (0.5) purely to
  catch pathological regressions (losing the sort-materialization anchor
  shows up as +100..400% here).  Steady-state walls only (min over ``reps``
  cached runs; compile split out per the bench-timing convention), asserting
  the traced trajectory is BIT-IDENTICAL to the untraced one on both cells.
* **live-metric overhead** (ISSUE 9) — the same paper-scale cell through the
  chunked runner (`run_chunks`: host loop over jitted scans with donated
  carries) twice: ``metrics=None`` vs a compiled-in `MetricSpec` ring whose
  flushes stream through a background `MetricWriter` to ``metrics.jsonl``.
  The full run measures the M = 512 replication workload against the < 10%
  acceptance budget; ``--smoke`` runs M = 128 with a noise-bound loose gate.
  Asserts the metrics-on trajectory is BIT-IDENTICAL to metrics-off and that
  the streamed row set is gapless.  The run leaves the full live-telemetry
  artifact set in ``OUT/live`` — ``metrics.jsonl`` + ``manifest.json`` +
  ``events.jsonl`` + an exported Perfetto ``trace.json`` — so CI uploads a
  dir that `python -m repro.obs.monitor` can render as a "killed run".
* **forensics are actionable** — a traced M = 64 grid (rule x attack cells,
  known Byzantine mask) written out as the real artifact set: ``events.jsonl``
  (`repro.obs.events.EventLog`), ``obs_summary.json`` (per-cell
  `repro.obs.trace.summarize`), and the rendered ``report.txt``.  The bench
  asserts the per-edge trim-frequency counters rank true Byzantine in-edges
  above honest edges (Mann-Whitney AUC) for every screening rule traced.

CI gates the timing metrics against ``benchmarks/baselines/BENCH_obs.json``
(`benchmarks.check_regression`; the baseline is smoke-sized, matching the CI
invocation — see scale_bench for the convention) and uploads the artifact
dir, so a traced run's event log and forensics report are inspectable on
every PR.

    PYTHONPATH=src python -m benchmarks.obs_bench [--smoke] [--out DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import erdos_renyi, replicate
from repro.core.bridge import stack_batches
from repro.core.graph import small_world
from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer, ChannelConfig
from repro.obs import (AlertRules, EventLog, MetricSpec, MetricWriter,
                       TraceSpec, read_events, write_manifest)
from repro.obs import perfetto as obs_perfetto
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.obs.metrics import read_metrics
from repro.sim import ExperimentGrid, GridEngine

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_obs.json")

RULE = "trimmed_mean"
B = 2
NEAREST = 6  # small-world ring degree per side -> K <= 16 after rewiring
DIM = 64


def _build(num_nodes: int, trace: TraceSpec | None, seed: int = 0,
           paper: bool = False, metrics: MetricSpec | None = None):
    """One sparse small-world BRIDGE cell.  ``paper=False``: a synthetic
    quadratic at d = 64, where the screening/obs work dominates — the worst
    case for the overhead ratio.  ``paper=True``: the replication workload
    (scale_bench's MNIST-like linear task, d = 7850)."""
    topo = small_world(num_nodes, NEAREST, B, rewire_prob=0.2, seed=seed)
    if paper:
        from benchmarks.scale_bench import _task

        grad_fn, init_fn, batch_fn = _task(num_nodes, dim_small=False, seed=seed)
        params = init_fn(seed)
    else:
        rng = np.random.default_rng(seed)
        targets = jnp.asarray(rng.normal(size=(num_nodes, DIM)), jnp.float32)

        def grad_fn(params, batch):
            w = params["w"]
            loss = 0.5 * jnp.sum((w - batch) ** 2)
            return loss, {"w": w - batch}

        batch_fn = lambda i: targets
        params = replicate({"w": jnp.zeros(DIM)}, num_nodes, perturb=0.1,
                           key=jax.random.PRNGKey(seed))
    cfg = AsyncBridgeConfig(
        topology=topo, rule=RULE, num_byzantine=B, attack="alie",
        channel=ChannelConfig(drop_prob=0.05), staleness_bound=2,
        lam=1.0, t0=100.0, sparse=True, trace=trace, metrics=metrics,
    )
    tr = AsyncBridgeTrainer(cfg, grad_fn)
    state = tr.init(params, seed=seed)
    return tr, state, batch_fn


def _steady_wall(tr, state, batches, ticks: int, reps: int):
    """(min steady wall over reps, compile_s, final state): first call pays
    trace + compile; the min over cached re-runs is the honest scan cost."""
    t0 = time.perf_counter()
    st, _ = tr.run_scan(state, batches)
    jax.block_until_ready(st.params)
    wall_first = time.perf_counter() - t0
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        st, _ = tr.run_scan(state, batches)
        jax.block_until_ready(st.params)
        walls.append(time.perf_counter() - t0)
    steady = min(walls)
    return steady, max(wall_first - steady, 0.0), st


def trace_overhead(num_nodes: int, ticks: int, reps: int, budget: float,
                   *, paper: bool = False, decide_stride: int = 4) -> dict:
    # aggregate-only: forensics counters, no reservoir.  decide_stride is the
    # production large-run config — the membership sweep samples every
    # stride-th coordinate; the forensics AUC below is measured under the
    # SAME spec, so the gate certifies the config whose overhead is quoted
    spec = TraceSpec(decide_stride=decide_stride)
    tr_off, st_off, bf = _build(num_nodes, None, paper=paper)
    tr_on, st_on, _ = _build(num_nodes, spec, paper=paper)
    # materialize the batch stack ONCE: stack_node_batches closures are
    # stateful (the rng advances per call), and the bit-identity check is
    # meaningless unless both runs scan the same draws
    batches = stack_batches(bf, ticks)
    steady_off, compile_off, fin_off = _steady_wall(tr_off, st_off, batches, ticks, reps)
    steady_on, compile_on, fin_on = _steady_wall(tr_on, st_on, batches, ticks, reps)
    identical = bool(jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all(a == b)), fin_off.params, fin_on.params)))
    overhead = steady_on / steady_off - 1.0

    # forensics from the SAME traced run: do the counters separate the known
    # Byzantine senders under an adaptive-style attack?
    senders = obs_trace.sender_grid(num_nodes, neighbors=tr_on.runtime.neighbors)
    summary = obs_trace.summarize(spec, fin_on.obs,
                                  byz_mask=np.asarray(tr_on.byz_mask), senders=senders)
    d = sum(leaf.size for leaf in jax.tree_util.tree_leaves(fin_on.params)) // num_nodes
    return {
        "num_nodes": num_nodes, "k": int(tr_on.runtime.neighbors.k),
        "dim": d, "ticks": ticks, "reps": reps,
        "decide_stride": decide_stride,
        "untraced_us_per_tick": steady_off / ticks * 1e6,
        "traced_us_per_tick": steady_on / ticks * 1e6,
        "untraced_steady_state_s": steady_off, "traced_steady_state_s": steady_on,
        "untraced_compile_s": compile_off, "traced_compile_s": compile_on,
        "overhead_frac": overhead, "overhead_budget": budget,
        "bit_identical": identical,
        "auc_byzantine_edges": summary["auc_byzantine_edges"],
        "survival": summary["survival"],
    }


def _steady_wall_chunks(tr, state, batch_at, ticks: int, reps: int, *,
                        writer=None, events=None):
    """`_steady_wall` for the chunked runner.  `run_chunks` donates its state
    carry, so each run starts from a fresh device-side copy (made OUTSIDE the
    timer) instead of the possibly-invalidated original."""
    tree = jax.tree_util.tree_map

    def once():
        st = tree(jnp.copy, state)
        t0 = time.perf_counter()
        st, _ = tr.run_chunks(st, batch_at, ticks, writer=writer, events=events)
        jax.block_until_ready(st.params)
        return time.perf_counter() - t0, st

    wall_first, st = once()
    walls = []
    for _ in range(reps):
        w, st = once()
        walls.append(w)
    steady = min(walls)
    return steady, max(wall_first - steady, 0.0), st


def metrics_overhead(num_nodes: int, ticks: int, reps: int, budget: float,
                     *, paper: bool = False, live_dir: str | None = None,
                     capacity: int | None = None) -> dict:
    """Metrics-off vs metrics-on through `run_chunks` on the same cell as
    `trace_overhead`.  The on-run streams to ``live_dir`` through a real
    `MetricWriter` (+ EventLog + manifest + Perfetto export), so the quoted
    overhead includes the device-side ring copy, the enqueue, and the
    background drain — the whole production path, not just the in-graph
    fold."""
    # capacity < ticks: the ring wraps and the host loop runs >= 2 chunks
    # (a full-width chunk AND the flush-before-overwrite discipline are both
    # on the measured path)
    capacity = capacity if capacity is not None else max(ticks // 2, 1)
    tr_off, st_off, bf = _build(num_nodes, None, paper=paper)
    tr_on, st_on, _ = _build(num_nodes, None, paper=paper,
                             metrics=MetricSpec(capacity=capacity))
    batches = stack_batches(bf, ticks)
    batch_at = lambda i: jax.tree_util.tree_map(lambda x: x[i], batches)
    steady_off, compile_off, fin_off = _steady_wall_chunks(
        tr_off, st_off, batch_at, ticks, reps)
    writer = events = None
    artifacts = {}
    if live_dir is not None:
        os.makedirs(live_dir, exist_ok=True)
        write_manifest(live_dir, kind="obs-bench-live",
                       config={"num_nodes": num_nodes, "ticks": ticks,
                               "reps": reps, "paper": paper,
                               "capacity": capacity})
        events = EventLog(os.path.join(live_dir, "events.jsonl"))
        writer = MetricWriter(os.path.join(live_dir, "metrics.jsonl"),
                              alerts=AlertRules(), events=events)
    steady_on, compile_on, fin_on = _steady_wall_chunks(
        tr_on, st_on, batch_at, ticks, reps, writer=writer, events=events)
    rows = None
    if writer is not None:
        writer.close()
        events.close()
        write_manifest(live_dir, extra={"ended": True,
                                        "steady_state_s": steady_on})
        # rep re-runs replay ticks 0..T-1; the writer dedups by tick, so the
        # artifact stream is exactly one row per tick
        rows = len(read_metrics(os.path.join(live_dir, "metrics.jsonl")))
        trace_path = obs_perfetto.export(live_dir)
        artifacts = {"metrics": os.path.join(live_dir, "metrics.jsonl"),
                     "manifest": os.path.join(live_dir, "manifest.json"),
                     "events": os.path.join(live_dir, "events.jsonl"),
                     "perfetto": trace_path}
    identical = bool(jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all(a == b)), fin_off.params, fin_on.params)))
    overhead = steady_on / steady_off - 1.0
    d = sum(leaf.size for leaf in jax.tree_util.tree_leaves(fin_on.params)) // num_nodes
    return {
        "num_nodes": num_nodes, "k": int(tr_on.runtime.neighbors.k),
        "dim": d, "ticks": ticks, "reps": reps, "capacity": capacity,
        "metrics_off_us_per_tick": steady_off / ticks * 1e6,
        "metrics_on_us_per_tick": steady_on / ticks * 1e6,
        "metrics_off_steady_state_s": steady_off,
        "metrics_on_steady_state_s": steady_on,
        "metrics_off_compile_s": compile_off, "metrics_on_compile_s": compile_on,
        "overhead_frac": overhead, "overhead_budget": budget,
        "bit_identical": identical, "rows_streamed": rows,
        "artifacts": artifacts,
    }


def traced_grid_artifacts(out_dir: str, num_nodes: int = 64, ticks: int = 40,
                          seed: int = 0) -> dict:
    """The CI artifact set: a traced M=64 grid run leaving ``events.jsonl``
    + ``obs_summary.json`` + rendered ``report.txt`` in ``out_dir``."""
    os.makedirs(out_dir, exist_ok=True)
    topo = erdos_renyi(num_nodes, 0.2, B, seed=seed)
    rng = np.random.default_rng(seed)
    targets = jnp.asarray(rng.normal(size=(num_nodes, 8)), jnp.float32)

    def grad_fn(params, batch):
        w = params["w"]
        loss = 0.5 * jnp.sum((w - batch) ** 2)
        return loss, {"w": w - batch}

    def init_fn(s):
        return replicate({"w": jnp.zeros(8)}, num_nodes, perturb=0.1,
                         key=jax.random.PRNGKey(s))

    spec = TraceSpec(reservoir=4, stride=max(ticks // 4, 1))
    events_path = os.path.join(out_dir, "events.jsonl")
    grid = ExperimentGrid(topo, ("trimmed_mean", "median"), ("alie",), (B,),
                          (seed,), lam=1.0, t0=30.0)
    with EventLog(events_path) as ev:
        engine = GridEngine(grid, grad_fn, trace=spec, events=ev,
                            # two compiled chunks so grid.chunk events land
                            # in the artifact log CI uploads
                            )
        state = engine.init(init_fn)
        final, metrics = engine.run(state, stack_batches(lambda i: targets, ticks),
                                    chunk=1)
    senders = engine.sender_grid()
    cells = []
    for i, c in enumerate(engine.cells):
        obs_i = jax.tree_util.tree_map(lambda leaf: leaf[i], final.obs)
        cells.append({"tag": c.tag, "rule": c.rule,
                      **obs_trace.summarize(spec, obs_i,
                                            byz_mask=engine.byz_masks[i],
                                            senders=senders)})
    summary = {"meta": {"kind": "obs_bench", "num_nodes": num_nodes,
                        "ticks": ticks, "events": events_path},
               "cells": cells}
    summary_path = os.path.join(out_dir, "obs_summary.json")
    with open(summary_path, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
    report = obs_report.render(summary, read_events(events_path))
    report_path = os.path.join(out_dir, "report.txt")
    with open(report_path, "w") as f:
        f.write(report)
    return {
        "num_nodes": num_nodes, "ticks": ticks,
        "cells": [{"tag": c["tag"], "rule": c["rule"],
                   "auc_byzantine_edges": c["auc_byzantine_edges"],
                   "byz_trim_freq": c["survival"]["byz_trim_freq"],
                   "honest_trim_freq": c["survival"]["honest_trim_freq"]}
                  for c in cells],
        "events": len(read_events(events_path)),
        "artifacts": {"events": events_path, "summary": summary_path,
                      "report": report_path},
    }


def run(smoke: bool = False, out_dir: str | None = None) -> dict:
    out_dir = out_dir or os.path.join(_ROOT, "obs_run")
    live_dir = os.path.join(out_dir, "live")
    if smoke:
        m = 128  # CI-sized; walls are noise-bound, budgets are loose
        stress = trace_overhead(m, ticks=10, reps=2, budget=0.5)
        paper = trace_overhead(m, ticks=3, reps=2, budget=0.25,
                               paper=True, decide_stride=16)
        mets = metrics_overhead(m, ticks=4, reps=2, budget=0.25,
                                paper=True, live_dir=live_dir)
    else:
        m = 512
        stress = trace_overhead(m, ticks=20, reps=3, budget=0.5)
        # THE acceptance cells: < 10% on the M = 512 replication workload
        paper = trace_overhead(m, ticks=3, reps=2, budget=0.10,
                               paper=True, decide_stride=16)
        mets = metrics_overhead(m, ticks=4, reps=2, budget=0.10,
                                paper=True, live_dir=live_dir)
    artifacts = traced_grid_artifacts(out_dir)
    aucs = [c["auc_byzantine_edges"] for c in artifacts["cells"]]
    aucs.append(stress["auc_byzantine_edges"])
    record = {
        "backend": jax.default_backend(),
        "config": {"rule": RULE, "b": B, "smoke": smoke,
                   "topology": f"small_world(nearest={NEAREST})"},
        "overhead": {"paper_scale": paper, "screen_stress": stress},
        "metrics": {"paper_scale": mets},
        "forensics": artifacts,
        "acceptance": {
            "trace_bit_inert": bool(paper["bit_identical"]
                                    and stress["bit_identical"]),
            "overhead_within_budget": bool(
                paper["overhead_frac"] < paper["overhead_budget"]
                and stress["overhead_frac"] < stress["overhead_budget"]),
            "byzantine_edges_ranked": bool(
                all(a is not None and a >= 0.7 for a in aucs)),
            "metrics_bit_inert": bool(mets["bit_identical"]),
            "metrics_overhead_within_budget": bool(
                mets["overhead_frac"] < mets["overhead_budget"]),
            "metrics_stream_complete": bool(
                mets["rows_streamed"] == mets["ticks"]),
        },
    }
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (M=128 overhead cell, looser budget)")
    ap.add_argument("--out", default=None, metavar="DIR",
                    help="traced-run artifact dir (default: ./obs_run)")
    args = ap.parse_args(argv)
    record = run(smoke=args.smoke, out_dir=args.out)
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    for name, ov in record["overhead"].items():
        print(f"{name} M={ov['num_nodes']} K={ov['k']} d={ov['dim']}: untraced "
              f"{ov['untraced_us_per_tick']:.0f} us/tick vs traced "
              f"{ov['traced_us_per_tick']:.0f} us/tick -> "
              f"{ov['overhead_frac'] * 100:+.1f}% (budget "
              f"{ov['overhead_budget'] * 100:.0f}%, bit-identical: {ov['bit_identical']})")
    mv = record["metrics"]["paper_scale"]
    print(f"metrics M={mv['num_nodes']} d={mv['dim']}: off "
          f"{mv['metrics_off_us_per_tick']:.0f} us/tick vs on "
          f"{mv['metrics_on_us_per_tick']:.0f} us/tick -> "
          f"{mv['overhead_frac'] * 100:+.1f}% (budget "
          f"{mv['overhead_budget'] * 100:.0f}%, bit-identical: "
          f"{mv['bit_identical']}, rows: {mv['rows_streamed']})")
    for c in record["forensics"]["cells"]:
        print(f"  {c['tag']}: auc={c['auc_byzantine_edges']:.3f} "
              f"byz_trim={c['byz_trim_freq']:.3f} honest_trim={c['honest_trim_freq']:.3f}")
    print(f"artifacts -> {record['forensics']['artifacts']['report']}")
    print(f"wrote {BENCH_JSON}")
    acc = record["acceptance"]
    if not all(acc.values()):
        raise SystemExit(f"obs acceptance failed: {acc}")


if __name__ == "__main__":
    main()
