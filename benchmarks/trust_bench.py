"""Trust-layer benchmark: detect-and-expel certification + echo-protocol
detection quality — writes ``BENCH_trust.json``.

Three measurements (ISSUE 7 acceptance):

* **detect-and-expel beats static 2b+1** — two `BreakdownEngine` runs on the
  net runtime ("ideal" scenario — equivocation only exists per *message*)
  over the MNIST-like linear task with the moderate non-iid partition (the
  extreme partition confounds screening breakdown with honest data
  availability at large b): the static ``trimmed_mean`` arm, whose Table-II
  ``2b + 1`` in-degree requirement caps certification at b = (deg - 1) // 2,
  versus the ``rep_trimmed_mean`` + `TrustSpec` arm, whose detect-and-expel
  premise relaxes the degree requirement to ``b + 1`` (eviction removes
  attackers instead of out-voting them).  On the complete graph (degree
  M - 1) the static arm is structurally uncertifiable past the wall while
  the trust arm keeps certifying — the gate is ``bstar_rep_trust >
  bstar_static`` with the trust arm's honest test accuracy inside the same
  ``score_drop`` budget the static ladder is held to.
* **echo detection quality** — a net-runtime grid (complete graph — one-hop
  digest gossip needs *triangles*: a witness must share the sender AND be
  adjacent to the receiver) with one ``equivocate`` cell and one ``slander``
  cell, summarized by `repro.trust.summarize` against the known Byzantine
  mask.  Gates: equivocator in-edges are evicted (rate >= 0.8, suspicion
  AUC >= 0.9) with ZERO honest evictions, and the slander cell evicts
  NOTHING anywhere — <= b forged accusations can never meet the b + 1
  disagreeing-witness quorum, so framing honest senders is structurally
  impossible.
* **trust is inert until it acts** — a dense async cell run twice, trust off
  vs trust on with a plain (unweighted) rule and warmup beyond the horizon:
  the trajectories must be BIT-IDENTICAL (reputation only touches the tick
  through rule weights and the eviction mask), with the steady-state walls
  of both runs reported so `benchmarks.check_regression` gates the echo +
  reputation overhead alongside the other benches.

CI gates the timing metrics against ``benchmarks/baselines/BENCH_trust.json``
(the baseline is smoke-sized, matching the CI invocation — see scale_bench
for the convention).

    PYTHONPATH=src python -m benchmarks.trust_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.adversary.breakdown import BreakdownConfig, BreakdownEngine
from repro.core import complete_graph, replicate
from repro.core.bridge import stack_batches
from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer, ChannelConfig
from repro.sim import ExperimentGrid, GridEngine
from repro.sim.tasks import linear_task
from repro.trust import TrustSpec, summarize

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_trust.json")

DIM = 64


def _quadratic(num_nodes: int, seed: int = 0):
    """The d = 64 synthetic quadratic the obs bench uses: screening (and
    here, the echo protocol) is essentially the whole tick."""
    rng = np.random.default_rng(seed)
    targets = jnp.asarray(rng.normal(size=(num_nodes, DIM)), jnp.float32)

    def grad_fn(params, batch):
        w = params["w"]
        loss = 0.5 * jnp.sum((w - batch) ** 2)
        return loss, {"w": w - batch}

    def init_fn(s):
        return replicate({"w": jnp.zeros(DIM)}, num_nodes, perturb=0.1,
                         key=jax.random.PRNGKey(s))

    return grad_fn, init_fn, targets


def breakdown_study(num_nodes: int, ticks: int, b_max: int, *,
                    warmup: int = 4, score_drop: float = 0.15,
                    seeds=(0,)) -> dict:
    """The two certification arms (see module docstring).  Returns the per-arm
    b* plus the full probe ladders — the data behind ``fig_trust``."""
    topo = complete_graph(num_nodes, b_max)
    task = linear_task(num_nodes, ticks, partition="moderate",
                       num_train=2000, num_test=400, seed=0)
    cfg = BreakdownConfig(mode="ladder", seeds=seeds, b_max=b_max,
                          loss_ratio=50.0, score_drop=score_drop)
    arms = {}
    for name, rule, trust in (
            ("static", "trimmed_mean", None),
            ("rep_trust", "rep_trimmed_mean", TrustSpec(warmup=warmup))):
        engine = BreakdownEngine(
            topo, (rule,), ("equivocate",), task.grad_fn, task.init_fn,
            task.batches, lam=1.0, t0=30.0, config=cfg,
            eval_fn=task.eval_accuracy, scenario="ideal", trust=trust)
        result = engine.run()
        rrec = result["rules"][rule]
        arec = rrec["adversaries"]["equivocate"]
        arms[name] = {
            "rule": rule, "trust": trust is not None,
            "feasible_b": rrec["feasible_b"], "bstar": arec["bstar"],
            "probes": {b: {"survived": p["survived"],
                           "score": p.get("score")}
                       for b, p in arec["probes"].items()},
            "reference_score": result["rules"][rule].get("reference", {}).get("score"),
            "wall_s": result["meta"]["wall_s"],
        }
    return {
        "num_nodes": num_nodes, "ticks": ticks, "b_max": b_max,
        "partition": "moderate", "scenario": "ideal",
        "score_drop": score_drop,
        "static_wall_b": (num_nodes - 2) // 2,  # (deg - 1) // 2, deg = M - 1
        **arms,
    }


def detection_cells(num_nodes: int, ticks: int, b: int, *,
                    warmup: int = 4, seed: int = 0) -> dict:
    """One net-runtime grid, two cells: ``equivocate`` (must be evicted) and
    ``slander`` (must evict nothing — the b + 1 quorum holds)."""
    grad_fn, init_fn, targets = _quadratic(num_nodes, seed)
    topo = complete_graph(num_nodes, b)
    spec = TrustSpec(warmup=warmup)
    grid = ExperimentGrid(topo, ("rep_trimmed_mean",), ("none",), (b,), (seed,),
                          scenarios=("ideal",),
                          adversaries=("equivocate", "slander"),
                          lam=1.0, t0=30.0)
    engine = GridEngine(grid, grad_fn, num_ticks=ticks, trust=spec)
    state = engine.init(init_fn)
    t0 = time.perf_counter()
    final, _ = engine.run(state, stack_batches(lambda i: targets, ticks))
    jax.block_until_ready(final.params)
    wall = time.perf_counter() - t0
    senders = engine.sender_grid()
    cells = {}
    for i, cell in enumerate(engine.cells):
        trust_i = jax.tree_util.tree_map(lambda leaf: leaf[i], final.trust)
        rec = summarize(spec, trust_i, byz_mask=engine.byz_masks[i],
                        senders=senders)
        rec.pop("spec", None)
        cells[cell.adversary] = rec
    return {"num_nodes": num_nodes, "ticks": ticks, "b": b,
            "wall_s": wall, "cells": cells}


def _steady_wall(tr, state, batches, reps: int):
    """(min steady wall over reps, compile_s, final state) — first call pays
    trace + compile; the min over cached re-runs is the honest scan cost."""
    t0 = time.perf_counter()
    st, _ = tr.run_scan(state, batches)
    jax.block_until_ready(st.params)
    wall_first = time.perf_counter() - t0
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        st, _ = tr.run_scan(state, batches)
        jax.block_until_ready(st.params)
        walls.append(time.perf_counter() - t0)
    steady = min(walls)
    return steady, max(wall_first - steady, 0.0), st


def inertness_overhead(num_nodes: int, ticks: int, reps: int,
                       seed: int = 0) -> dict:
    """Trust-off vs trust-on-but-inert (plain rule, warmup > horizon) on a
    dense async cell: bit-identity + the echo/reputation wall cost."""
    grad_fn, init_fn, targets = _quadratic(num_nodes, seed)

    def build(trust):
        topo = complete_graph(num_nodes, 2)
        cfg = AsyncBridgeConfig(
            topology=topo, rule="trimmed_mean", num_byzantine=2, attack="alie",
            channel=ChannelConfig(drop_prob=0.05), staleness_bound=2,
            lam=1.0, t0=100.0, sparse=False, trust=trust)
        tr = AsyncBridgeTrainer(cfg, grad_fn)
        return tr, tr.init(init_fn(seed), seed=seed)

    batches = stack_batches(lambda i: targets, ticks)
    tr_off, st_off = build(None)
    # warmup past the horizon + a plain (unweighted) rule: reputation runs
    # but cannot act, so the trajectory must not move by a single bit
    tr_on, st_on = build(TrustSpec(warmup=ticks + 1))
    steady_off, compile_off, fin_off = _steady_wall(tr_off, st_off, batches, reps)
    steady_on, compile_on, fin_on = _steady_wall(tr_on, st_on, batches, reps)
    identical = bool(jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all(a == b)), fin_off.params, fin_on.params)))
    return {
        "num_nodes": num_nodes, "dim": DIM, "ticks": ticks, "reps": reps,
        "off_us_per_tick": steady_off / ticks * 1e6,
        "on_us_per_tick": steady_on / ticks * 1e6,
        "off_steady_state_s": steady_off, "on_steady_state_s": steady_on,
        "off_compile_s": compile_off, "on_compile_s": compile_on,
        "overhead_frac": steady_on / steady_off - 1.0,
        "bit_identical": identical,
    }


def run(smoke: bool = False) -> dict:
    if smoke:
        # 64 ticks, not shorter: the score_drop detector is tick-sensitive
        # (at 32 ticks the b = 6 probes of BOTH arms sit within noise of the
        # cutoff), and certification must not flap in CI
        breakdown = breakdown_study(15, ticks=64, b_max=7)
        detection = detection_cells(12, ticks=16, b=2)
        inert = inertness_overhead(32, ticks=12, reps=2)
    else:
        breakdown = breakdown_study(15, ticks=96, b_max=7)
        detection = detection_cells(16, ticks=24, b=3)
        inert = inertness_overhead(64, ticks=20, reps=3)
    equiv = detection["cells"]["equivocate"]
    sland = detection["cells"]["slander"]
    record = {
        "backend": jax.default_backend(),
        "config": {"smoke": smoke, "topology": "complete"},
        "breakdown": breakdown,
        "detection": detection,
        "inertness": inert,
        "acceptance": {
            # the headline: detect-and-expel certifies past the static
            # 2b + 1 wall (and the trust arm genuinely survives up there)
            "detect_and_expel_beats_static": bool(
                breakdown["rep_trust"]["bstar"] > breakdown["static"]["bstar"]),
            "equivocators_detected": bool(
                equiv["byz_eviction_rate"] >= 0.8
                and (equiv["auc_byzantine_edges"] or 0.0) >= 0.9),
            "honest_eviction_rate_zero": bool(
                equiv["honest_evicted"] == 0 and sland["honest_evicted"] == 0),
            # honest receivers evict NO edge under slander — the forged
            # accusations can't reach quorum.  (Slanderers do evict their own
            # in-edges: their self-corrupted digests disagree with every
            # honest witness.  Those rows belong to attackers and are
            # excluded from summarize's honest-view eviction counts.)
            "slander_evicts_nothing": bool(
                sland["honest_evicted"] == 0 and sland["byz_evicted"] == 0),
            "trust_bit_inert": bool(inert["bit_identical"]),
        },
    }
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer ticks, smaller cells)")
    args = ap.parse_args(argv)
    record = run(smoke=args.smoke)
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    bd = record["breakdown"]
    print(f"breakdown (M={bd['num_nodes']}, complete graph, equivocate): "
          f"static {bd['static']['rule']} b*={bd['static']['bstar']} "
          f"(feasibility wall b={bd['static_wall_b']}) vs "
          f"rep+trust b*={bd['rep_trust']['bstar']}")
    for adv, rec in record["detection"]["cells"].items():
        print(f"  {adv}: evicted={rec['edges_evicted']} "
              f"byz_rate={rec['byz_eviction_rate']:.2f} "
              f"honest_evicted={rec['honest_evicted']} "
              f"auc={rec['auc_byzantine_edges']}")
    inert = record["inertness"]
    print(f"inertness M={inert['num_nodes']}: off "
          f"{inert['off_us_per_tick']:.0f} us/tick vs on "
          f"{inert['on_us_per_tick']:.0f} us/tick -> "
          f"{inert['overhead_frac'] * 100:+.1f}% "
          f"(bit-identical: {inert['bit_identical']})")
    print(f"wrote {BENCH_JSON}")
    acc = record["acceptance"]
    if not all(acc.values()):
        raise SystemExit(f"trust acceptance failed: {acc}")


if __name__ == "__main__":
    main()
