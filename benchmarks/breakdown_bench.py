"""Breakdown-certification benchmark: certified b* per screening rule under
static AND adaptive adversaries, on the MNIST-like linear task with the
extreme non-iid partition (consensus is *required* for honest test accuracy
— exactly what adaptive adversaries break).

Emits ``BENCH_breakdown.json`` for the CI artifact + regression gate:

* per (rule, adversary): the monotone-certified breakdown point b* and the
  full probe ladder (honest loss + honest test accuracy per b) — the
  ``fig_breakdown`` curve data;
* acceptance booleans: every rule has a monotone-certified b*, and at least
  one adaptive adversary (inner-maximization / IPM family) achieves strictly
  worse honest test error than the best static attack at equal b — the
  red-team subsystem's reason to exist.  The bench FAILS if that inversion
  disappears (mirroring grid_bench's divergence gate);
* wall-time metrics (``wall_s``, ``cells_per_sec``) for
  ``benchmarks.check_regression``.

    PYTHONPATH=src python -m benchmarks.breakdown_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os

from repro.adversary.breakdown import BreakdownConfig, BreakdownEngine
from repro.sim import default_topology
from repro.sim.tasks import linear_task

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_breakdown.json")

STATIC = ("random", "alie")
ADAPTIVE = ("ipm", "inner_max")


def run_certification(num_nodes=10, ticks=60, *, rules=("trimmed_mean", "median"),
                      adversaries=STATIC + ADAPTIVE, b_max=3, seeds=(0,),
                      mode="ladder", score_drop=0.25, loss_ratio=50.0):
    """Certify b* for every (rule, adversary) pair; returns the result dict
    (probe ladders carry honest loss and honest test accuracy per b).

    Breakdown on this task is an *accuracy* event (honest nodes retreat to
    their local shards — local loss can stay small while the global model is
    gone), so the primary detector is the test-accuracy drop; the loss-ratio
    detector is set high to catch outright blowups only.
    """
    # same data sizes as benchmarks.common.get_data (the other paper benches)
    task = linear_task(num_nodes, ticks, num_train=4000, num_test=800, seed=0)
    # the shared topology must admit the whole probed ladder, not just b=1
    topo = default_topology(num_nodes, rules, (max(b_max, 1),), seed=0)
    engine = BreakdownEngine(
        topo, rules, adversaries, task.grad_fn, task.init_fn, task.batches,
        lam=1.0, t0=30.0,
        config=BreakdownConfig(mode=mode, seeds=seeds, b_max=b_max,
                               loss_ratio=loss_ratio, score_drop=score_drop,
                               measure_compile=True),
        eval_fn=task.eval_accuracy)
    result = engine.run()
    result["meta"]["num_nodes"] = num_nodes
    result["meta"]["ticks"] = ticks
    return result


def _acceptance(result: dict, b_eq: int) -> dict:
    """The two acceptance booleans (see module docstring)."""
    monotone = all("bstar_worst_adversary" in rrec and all(
        arec.get("certified_monotone") for arec in rrec["adversaries"].values())
        for rrec in result["rules"].values())
    inversion = {}
    for rule, rrec in result["rules"].items():
        advs = rrec["adversaries"]

        def err_at(names):
            errs = []
            for n in names:
                probe = advs.get(n, {}).get("probes", {}).get(str(b_eq))
                if probe is not None and "score" in probe:
                    errs.append(1.0 - probe["score"])
            return errs

        static_err, adaptive_err = err_at(STATIC), err_at(ADAPTIVE)
        if static_err and adaptive_err:
            inversion[rule] = {
                "b": b_eq,
                "best_static_error": max(static_err),
                "best_adaptive_error": max(adaptive_err),
                "adaptive_strictly_worse_for_honest":
                    max(adaptive_err) > max(static_err),
            }
    return {
        "all_rules_certified_monotone": bool(monotone),
        # None when no rule has both tiers probed at b_eq (bisect mode may
        # legitimately skip it) — the gate only bites on real comparisons
        "adaptive_beats_static_somewhere": any(
            rec["adaptive_strictly_worse_for_honest"] for rec in inversion.values())
        if inversion else None,
        "per_rule": inversion,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced run (fewer ticks) for quick local checks")
    ap.add_argument("--nodes", type=int, default=10)
    ap.add_argument("--ticks", type=int, default=60)
    ap.add_argument("--b-max", type=int, default=3)
    ap.add_argument("--mode", default="ladder", choices=["ladder", "bisect"])
    args = ap.parse_args(argv)
    ticks = 30 if args.smoke else args.ticks

    result = run_certification(args.nodes, ticks, b_max=args.b_max, mode=args.mode)
    result["acceptance"] = _acceptance(result, b_eq=min(2, args.b_max))
    with open(BENCH_JSON, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)

    print("name,us_per_call,derived")
    meta = result["meta"]
    for rule, rrec in result["rules"].items():
        stars = ";".join(f"{a}=b{arec['bstar']}"
                         for a, arec in rrec["adversaries"].items())
        print(f"breakdown/{rule},{meta['wall_s'] / max(meta['cells_run'], 1) * 1e6:.1f},"
              f"feasible={rrec['feasible_b']};{stars};"
              f"worst={rrec['bstar_worst_adversary']}")
    acc = result["acceptance"]
    print(f"breakdown/acceptance,0.0,"
          f"monotone={acc['all_rules_certified_monotone']};"
          f"adaptive_beats_static={acc['adaptive_beats_static_somewhere']}")
    if not acc["all_rules_certified_monotone"]:
        raise RuntimeError("breakdown certification lost monotonicity — see BENCH_breakdown.json")
    if acc["adaptive_beats_static_somewhere"] is False:
        raise RuntimeError(
            "no adaptive adversary beats the best static attack at equal b — "
            "the red-team harness has regressed; see BENCH_breakdown.json")
    if acc["adaptive_beats_static_somewhere"] is None:
        print("[warn] no (rule, b) point had both tiers probed — "
              "adaptive-vs-static comparison skipped (use --mode ladder)")


if __name__ == "__main__":
    main()
