"""Grid-engine throughput benchmark: one-compile vmapped sweep vs the
subprocess sweep baseline.

Runs a rule x attack x seed grid on the paper's MNIST-like linear task three
ways:

* **grid** — every cell inside one jitted program (`repro.sim.GridEngine`);
  wall time INCLUDES the single compilation.
* **subprocess baseline** — real ``python -m repro.launch.sweep --mode grid``
  single-cell invocations (fresh interpreter + jax import + data + trace +
  compile per cell — exactly what the subprocess fan-out pays), measured on
  ``baseline_cells`` cells and extrapolated.
* **sequential in-process baseline** — a fresh `BridgeTrainer` per cell in
  this process (no interpreter/import cost): the lower bound any
  per-cell-process design could hope for.

Emits ``BENCH_grid.json`` (cells/sec each way, speedup, trace count) for the
CI artifact + regression gate, and CSV rows for `benchmarks.run`.  The grid
run also cross-checks a sample cell against its in-process sequential twin
(recording the max deviation — the protocol pipeline is bit-identical by
construction, the model's multithreaded CPU GEMMs may drift at ULP level),
so the speedup number can't silently come from computing something
different.

    PYTHONPATH=src python -m benchmarks.grid_bench [--smoke] [--chunk N]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_accuracy, get_data, make_grad_fn
from repro.core import BridgeConfig, BridgeTrainer, replicate
from repro.data import partition_iid
from repro.data.partition import stack_node_batches
from repro.models import small
from repro.sim import ExperimentGrid, GridEngine
from repro.sim.engine import stack_batches

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_grid.json")


def _subprocess_cell_seconds(cells, num_nodes, ticks) -> float:
    """Mean wall time of a real one-cell subprocess sweep (the per-cell cost
    of the subprocess fan-out this engine replaces)."""
    walls = []
    for c in cells:
        out = tempfile.mkdtemp(prefix="grid_base_")
        cmd = [
            sys.executable, "-m", "repro.launch.sweep", "--mode", "grid",
            "--rules", c.rule, "--attacks", c.attack, "--byz", str(c.b),
            "--seeds", str(c.seed), "--grid-nodes", str(num_nodes),
            "--grid-ticks", str(ticks), "--out", out,
        ]
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(_ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
        t0 = time.perf_counter()
        proc = subprocess.run(cmd, capture_output=True, text=True, cwd=_ROOT, env=env)
        walls.append(time.perf_counter() - t0)
        shutil.rmtree(out, ignore_errors=True)
        if proc.returncode != 0:
            raise RuntimeError(f"baseline subprocess failed: {proc.stderr[-2000:]}")
    return float(np.mean(walls))


def grid_throughput(
    num_nodes: int = 12,
    ticks: int = 30,
    *,
    rules=("trimmed_mean", "median"),
    attacks=("random", "alie", "sign_flip"),
    num_byzantine: int = 2,
    seeds=tuple(range(8)),
    chunk: int | None = None,
    baseline_cells: int = 2,
    subprocess_baseline: bool = True,
    seed: int = 0,
):
    """Returns CSV rows and writes BENCH_grid.json."""
    from repro.sim.grid import default_topology

    x, y, xt, yt = get_data()
    shards = partition_iid(x, y, num_nodes, seed=seed)
    # stack_node_batches closures are stateful (the rng advances per call):
    # every consumer gets a FRESH closure so all paths see the same draws
    fresh_batch_fn = lambda: stack_node_batches(shards, 32, seed=seed)
    topo = default_topology(num_nodes, rules, (num_byzantine,), seed=seed)
    grad_fn = make_grad_fn("linear")
    bf = fresh_batch_fn()
    batches = stack_batches(
        lambda i: jax.tree_util.tree_map(jnp.asarray, bf(i)), ticks)

    def init_fn(s):
        key = jax.random.PRNGKey(s)
        return replicate(small.init_linear(key), num_nodes, perturb=0.01, key=key)

    grid = ExperimentGrid(topo, rules, attacks, (num_byzantine,), seeds, lam=1.0, t0=30.0)
    engine = GridEngine(grid, grad_fn)
    e = engine.num_cells

    t0 = time.perf_counter()
    state0 = engine.init(init_fn)
    state, metrics = engine.run(state0, batches, chunk=chunk)
    jax.block_until_ready(state.params)
    wall_grid = time.perf_counter() - t0
    grid_cps = e / wall_grid
    # the sweep's one compile is part of the amortized story (wall_s keeps
    # it), but re-running the now-cached program splits it out so the gate
    # can track scan cost and compile cost separately
    t0 = time.perf_counter()
    jax.block_until_ready(engine.run(state0, batches, chunk=chunk)[0].params)
    wall_steady = time.perf_counter() - t0
    compile_s = max(wall_grid - wall_steady, 0.0)

    # in-process sequential baseline: fresh trainer (trace + compile) per cell
    n_base = min(baseline_cells, e)
    t0 = time.perf_counter()
    base_final = {}
    for c in engine.cells[:n_base]:
        cfg = BridgeConfig(topology=topo, rule=c.rule, num_byzantine=c.b,
                           attack=c.attack, lam=1.0, t0=30.0)
        tr = BridgeTrainer(cfg, make_grad_fn("linear"))
        st = tr.init(init_fn(c.seed), seed=c.seed)
        bf = fresh_batch_fn()  # same draw sequence the grid scanned over
        for i in range(ticks):
            bx, by = bf(i)
            st, _ = tr.step(st, (jnp.asarray(bx), jnp.asarray(by)))
        jax.block_until_ready(st.params)
        base_final[c.tag] = st.params
    wall_seq = time.perf_counter() - t0
    seq_cps = n_base / wall_seq

    # subprocess baseline: what the fan-out sweep actually pays per cell
    if subprocess_baseline:
        sub_s = _subprocess_cell_seconds(engine.cells[:n_base], num_nodes, ticks)
        sub_cps = 1.0 / sub_s
    else:  # pragma: no cover - smoke-speed escape hatch
        sub_s, sub_cps = None, seq_cps

    # correctness anchor: the measured speedup compares identical experiments.
    # The protocol pipeline (attack/screen/update) is bit-identical by
    # construction (property-tested in tests/test_grid.py); the model's GEMM
    # reductions may drift at ULP level under multithreaded CPU batching, so
    # the bench records the observed max deviation and gates on allclose.
    sample = engine.cells[0]
    diffs = [
        float(np.max(np.abs(np.asarray(leaf_g[0], np.float64) - np.asarray(leaf_s, np.float64))))
        for leaf_g, leaf_s in zip(
            jax.tree_util.tree_leaves(state.params),
            jax.tree_util.tree_leaves(base_final[sample.tag]),
            strict=True,
        )
    ]
    max_diff = max(diffs)
    same = max_diff <= 1e-5
    speedup = grid_cps / sub_cps
    acc = eval_accuracy(
        "linear",
        jax.tree_util.tree_map(lambda leaf: leaf[0], state.params),
        ~engine.byz_masks[0], jnp.asarray(xt), jnp.asarray(yt),
    )
    record = {
        "grid": {
            "cells": e, "ticks": ticks, "num_nodes": num_nodes,
            "chunk": chunk, "wall_s": wall_grid, "cells_per_sec": grid_cps,
            "compile_s": compile_s, "steady_state_s": wall_steady,
            "trace_count": engine.trace_count,
            "rules": list(rules), "attacks": list(attacks), "seeds": list(seeds),
        },
        "subprocess_baseline": {
            "cells_measured": n_base, "seconds_per_cell": sub_s,
            "cells_per_sec": sub_cps,
            "extrapolated_wall_s_all_cells": e / sub_cps,
        },
        "sequential_inprocess_baseline": {
            "cells_measured": n_base, "wall_s": wall_seq, "cells_per_sec": seq_cps,
        },
        "speedup_vs_subprocess": speedup,
        "speedup_vs_sequential_inprocess": grid_cps / seq_cps,
        "sample_cell_allclose": bool(same),
        "sample_cell_max_abs_diff": max_diff,
        "sample_cell_accuracy": float(acc),
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    if not same:
        raise RuntimeError(
            f"grid/sequential divergence: sample cell {sample.tag} differs by "
            f"{max_diff:.3g} (> 1e-5) — the speedup would compare different "
            f"computations; see BENCH_grid.json"
        )
    rows = [
        ("grid/engine", wall_grid / e * 1e6,
         f"cells={e};cells_per_sec={grid_cps:.3f};trace_count={engine.trace_count}"),
        ("grid/subprocess_baseline", 0.0 if sub_s is None else sub_s * 1e6,
         f"cells={n_base};cells_per_sec={sub_cps:.3f}"),
        ("grid/sequential_baseline", wall_seq / n_base * 1e6,
         f"cells={n_base};cells_per_sec={seq_cps:.3f}"),
        ("grid/speedup", 0.0,
         f"x{speedup:.1f}_vs_subprocess;sample_allclose={same};acc={acc:.4f}"),
    ]
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid for quick local runs (fewer seeds, "
                         "no subprocess baseline)")
    ap.add_argument("--nodes", type=int, default=12)
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--chunk", type=int, default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        kw = dict(seeds=(0, 1), ticks=20, baseline_cells=1, subprocess_baseline=False)
    else:
        kw = dict(ticks=args.ticks)
    print("name,us_per_call,derived")
    for name, us, derived in grid_throughput(args.nodes, chunk=args.chunk, **kw):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
