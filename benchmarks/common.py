"""Shared harness for the paper-replication benchmarks (MNIST-like scale).

Settings mirror Sec. V at CI-friendly size: the paper uses 50 nodes on MNIST;
we default to 20 nodes on the synthetic MNIST-like set (the qualitative
orderings — DGD collapse, BRIDGE resilience, ByRDiE communication overhead —
are scale-stable).  Pass ``--full`` to run.py for 50 nodes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BridgeConfig, BridgeTrainer, erdos_renyi, replicate
from repro.data import make_mnist_like, partition_extreme_noniid, partition_iid, partition_moderate_noniid
from repro.data.partition import stack_node_batches
from repro.models import small

_DATA = {}


def get_data(num_train=4000, num_test=800):
    key = (num_train, num_test)
    if key not in _DATA:
        _DATA[key] = make_mnist_like(num_train, num_test, seed=0)
    return _DATA[key]


def make_grad_fn(model: str):
    if model == "linear":
        def fn(params, batch):
            return jax.value_and_grad(lambda p: small.linear_loss(p, batch))(params)
        return fn
    def fn(params, batch):
        x, y = batch
        x = x.reshape(-1, 28, 28, 1)
        return jax.value_and_grad(lambda p: small.cnn_loss(p, (x, y)))(params)
    return fn


def eval_accuracy(model: str, params_stacked, honest_mask, x_test, y_test):
    """Average test accuracy over honest nodes (paper's metric)."""
    hm = np.asarray(honest_mask)
    accs = []
    for j in np.nonzero(hm)[0]:
        p = jax.tree_util.tree_map(lambda l: l[j], params_stacked)
        if model == "linear":
            accs.append(float(small.linear_accuracy(p, x_test, y_test)))
        else:
            accs.append(float(small.cnn_accuracy(p, x_test.reshape(-1, 28, 28, 1), y_test)))
    return float(np.mean(accs))


def run_decentralized(
    *,
    model: str = "linear",
    rule: str = "trimmed_mean",
    attack: str = "none",
    codec: str = "identity",
    num_nodes: int = 20,
    num_byzantine: int = 0,
    partition: str = "iid",
    steps: int = 120,
    batch: int = 32,
    lam: float = 1.0,
    t0: float = 30.0,
    seed: int = 0,
    eval_every: int = 0,
):
    x, y, xt, yt = get_data()
    part = {
        "iid": partition_iid,
        "extreme": partition_extreme_noniid,
        "moderate": partition_moderate_noniid,
    }[partition]
    shards = part(x, y, num_nodes, seed=seed)
    batch_fn = stack_node_batches(shards, batch, seed=seed)
    topo = None
    for p in (0.5, 0.6, 0.7, 0.8, 0.9, 1.0):  # p=1.0 -> complete graph (bulyan b=4)
        try:
            cand = erdos_renyi(num_nodes, p, num_byzantine, seed=seed)
            cand.validate_for_rule(rule)  # bulyan/krum need larger degrees
            topo = cand
            break
        except (RuntimeError, ValueError):
            continue
    if topo is None:
        raise RuntimeError(f"no graph for rule={rule}, b={num_byzantine}, M={num_nodes}")
    cfg = BridgeConfig(topology=topo, rule=rule, num_byzantine=num_byzantine,
                       attack=attack, codec=codec, lam=lam, t0=t0)
    trainer = BridgeTrainer(cfg, make_grad_fn(model))
    key = jax.random.PRNGKey(seed)
    init = small.init_linear(key) if model == "linear" else small.init_cnn(key)
    params = replicate(init, num_nodes, perturb=0.01, key=key)
    state = trainer.init(params)
    t_start = time.perf_counter()
    curve = []
    for i in range(steps):
        bx, by = batch_fn(i)
        state, metrics = trainer.step(state, (jnp.asarray(bx), jnp.asarray(by)))
        if eval_every and (i + 1) % eval_every == 0:
            curve.append((i + 1, eval_accuracy(model, state.params, trainer.honest_mask, jnp.asarray(xt), jnp.asarray(yt))))
    wall = time.perf_counter() - t_start
    acc = eval_accuracy(model, state.params, trainer.honest_mask, jnp.asarray(xt), jnp.asarray(yt))
    return {
        "accuracy": acc,
        "consensus": float(metrics["consensus_dist"]),
        "loss": float(metrics["loss"]),
        "us_per_step": wall / steps * 1e6,
        "wire_bits_per_edge": float(metrics["wire_bits_per_edge"]),
        "curve": curve,
        "trainer": trainer,
        "state": state,
    }
