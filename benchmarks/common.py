"""Shared harness for the paper-replication benchmarks (MNIST-like scale).

Settings mirror Sec. V at CI-friendly size: the paper uses 50 nodes on MNIST;
we default to 20 nodes on the synthetic MNIST-like set (the qualitative
orderings — DGD collapse, BRIDGE resilience, ByRDiE communication overhead —
are scale-stable).  Pass ``--full`` to run.py for 50 nodes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BrdsoConfig,
    BrdsoTrainer,
    BridgeConfig,
    BridgeTrainer,
    ByrdieConfig,
    ByrdieTrainer,
    erdos_renyi,
    replicate,
)
from repro.data import make_mnist_like, partition_extreme_noniid, partition_iid, partition_moderate_noniid
from repro.data.partition import stack_node_batches
from repro.models import small

_DATA = {}


def get_data(num_train=4000, num_test=800):
    key = (num_train, num_test)
    if key not in _DATA:
        _DATA[key] = make_mnist_like(num_train, num_test, seed=0)
    return _DATA[key]


def make_grad_fn(model: str):
    if model == "linear":
        def fn(params, batch):
            return jax.value_and_grad(lambda p: small.linear_loss(p, batch))(params)
        return fn
    def fn(params, batch):
        x, y = batch
        x = x.reshape(-1, 28, 28, 1)
        return jax.value_and_grad(lambda p: small.cnn_loss(p, (x, y)))(params)
    return fn


def eval_accuracy(model: str, params_stacked, honest_mask, x_test, y_test):
    """Average test accuracy over honest nodes (paper's metric)."""
    hm = np.asarray(honest_mask)
    accs = []
    for j in np.nonzero(hm)[0]:
        p = jax.tree_util.tree_map(lambda l: l[j], params_stacked)
        if model == "linear":
            accs.append(float(small.linear_accuracy(p, x_test, y_test)))
        else:
            accs.append(float(small.cnn_accuracy(p, x_test.reshape(-1, 28, 28, 1), y_test)))
    return float(np.mean(accs))


def run_decentralized(
    *,
    model: str = "linear",
    rule: str = "trimmed_mean",
    attack: str = "none",
    adversary: str = "none",
    codec: str = "identity",
    num_nodes: int = 20,
    num_byzantine: int = 0,
    partition: str = "iid",
    steps: int = 120,
    batch: int = 32,
    lam: float = 1.0,
    t0: float = 30.0,
    seed: int = 0,
    eval_every: int = 0,
):
    x, y, xt, yt = get_data()
    part = {
        "iid": partition_iid,
        "extreme": partition_extreme_noniid,
        "moderate": partition_moderate_noniid,
    }[partition]
    shards = part(x, y, num_nodes, seed=seed)
    batch_fn = stack_node_batches(shards, batch, seed=seed)
    topo = None
    for p in (0.5, 0.6, 0.7, 0.8, 0.9, 1.0):  # p=1.0 -> complete graph (bulyan b=4)
        try:
            cand = erdos_renyi(num_nodes, p, num_byzantine, seed=seed)
            cand.validate_for_rule(rule)  # bulyan/krum need larger degrees
            topo = cand
            break
        except (RuntimeError, ValueError):
            continue
    if topo is None:
        raise RuntimeError(f"no graph for rule={rule}, b={num_byzantine}, M={num_nodes}")
    cfg = BridgeConfig(topology=topo, rule=rule, num_byzantine=num_byzantine,
                       attack=attack, adversary=adversary, codec=codec, lam=lam, t0=t0)
    trainer = BridgeTrainer(cfg, make_grad_fn(model))
    key = jax.random.PRNGKey(seed)
    init = small.init_linear(key) if model == "linear" else small.init_cnn(key)
    params = replicate(init, num_nodes, perturb=0.01, key=key)
    state = trainer.init(params)
    t_start = time.perf_counter()
    curve = []
    compile_s = 0.0
    for i in range(steps):
        bx, by = batch_fn(i)
        state, metrics = trainer.step(state, (jnp.asarray(bx), jnp.asarray(by)))
        if i == 0:
            # the first step's wall is dominated by tracing + XLA compilation;
            # conflating it with the scan cost hid both compile regressions
            # (amortized away) and steady-state regressions (drowned out)
            jax.block_until_ready(state.params)
            compile_s = time.perf_counter() - t_start
        if eval_every and (i + 1) % eval_every == 0:
            curve.append((i + 1, eval_accuracy(model, state.params, trainer.honest_mask, jnp.asarray(xt), jnp.asarray(yt))))
    jax.block_until_ready(state.params)
    wall = time.perf_counter() - t_start
    steady = max(wall - compile_s, 0.0)
    acc = eval_accuracy(model, state.params, trainer.honest_mask, jnp.asarray(xt), jnp.asarray(yt))
    return {
        "accuracy": acc,
        "consensus": float(metrics["consensus_dist"]),
        "loss": float(metrics["loss"]),
        # steady-state per-step cost (first/compiling step excluded)
        "us_per_step": steady / max(steps - 1, 1) * 1e6,
        "compile_s": compile_s,
        "steady_state_s": steady,
        "wire_bits_per_edge": float(metrics["wire_bits_per_edge"]),
        "curve": curve,
        "trainer": trainer,
        "state": state,
    }


def _baseline_setup(num_nodes, num_byzantine, partition, seed):
    """The shared linear task (repro.sim.tasks) at the paper benches' data
    sizes, plus the ByRDiE/BRDSO baseline topology."""
    from repro.sim.tasks import linear_task

    task = linear_task(num_nodes, 0, partition=partition,
                       num_train=4000, num_test=800, seed=seed)
    topo = erdos_renyi(num_nodes, 0.5, num_byzantine, seed=seed)
    return topo, task.batch_fn, task.init_fn(seed), task.x_test, task.y_test


def run_byrdie(*, num_nodes=20, num_byzantine=2, attack="random", sweeps=2,
               block=512, partition="iid", t0=30.0, seed=0):
    """ByRDiE baseline (coordinate descent, [58]) on the linear task — one
    sweep is d sequential scalar screening rounds; `block` trades gradient
    recomputation fidelity for wall time (communication accounting is exact
    either way)."""
    topo, batch_fn, params, xt, yt = _baseline_setup(num_nodes, num_byzantine, partition, seed)
    cfg = ByrdieConfig(topology=topo, num_byzantine=num_byzantine, attack=attack,
                       block=block, t0=t0)
    tr = ByrdieTrainer(cfg, make_grad_fn("linear"))
    st = tr.init(params)
    t_start = time.perf_counter()
    for i in range(sweeps):
        bx, by = batch_fn(i)
        st, m = tr.sweep(st, (jnp.asarray(bx), jnp.asarray(by)))
    wall = time.perf_counter() - t_start
    return {
        "accuracy": eval_accuracy("linear", st.params, ~tr.byz_mask, xt, yt),
        "loss": float(m["loss"]),
        "scalars_sent": float(m["scalars_sent"]),
        "us_per_step": wall / sweeps * 1e6,
    }


def run_brdso(*, num_nodes=20, num_byzantine=2, attack="random", steps=120,
              partition="iid", lam0=0.05, t0=30.0, seed=0):
    """BRDSO baseline (TV-penalty subgradient, [60]) on the linear task."""
    topo, batch_fn, params, xt, yt = _baseline_setup(num_nodes, num_byzantine, partition, seed)
    cfg = BrdsoConfig(topology=topo, num_byzantine=num_byzantine, attack=attack,
                      lam0=lam0, t0=t0)
    tr = BrdsoTrainer(cfg, make_grad_fn("linear"))
    st = tr.init(params)
    t_start = time.perf_counter()
    for i in range(steps):
        bx, by = batch_fn(i)
        st, m = tr.step(st, (jnp.asarray(bx), jnp.asarray(by)))
    wall = time.perf_counter() - t_start
    return {
        "accuracy": eval_accuracy("linear", st.params, ~tr.byz_mask, xt, yt),
        "loss": float(m["loss"]),
        "consensus": float(m["consensus_dist"]),
        "us_per_step": wall / steps * 1e6,
    }
