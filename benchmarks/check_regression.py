"""Benchmark regression gate: compare fresh ``BENCH_*.json`` files against
committed baselines and fail (exit 1) on wall-time regression.

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--fresh-dir .] [--baseline-dir benchmarks/baselines] \
        [--names BENCH_grid.json,BENCH_net.json] [--tol 1.5] [--update]

Metrics are discovered recursively by key name: keys ending in one of the
time suffixes (``us_per_tick``, ``us_per_step``, ``us_per_cell``, ``wall_s``,
``seconds_per_cell``) are *lower-is-better*; ``cells_per_sec`` is
*higher-is-better*.  A metric regresses when it is worse than the committed
baseline by more than ``--tol`` (default 1.5x, i.e. 50% slower; override per
run or via the ``BENCH_TOL`` env var — CI runners are noisy, paper over a
flaky gate by bumping the tolerance, not by deleting the step).

Re-baselining (after an intentional perf change, or to adopt a new runner
class): run the benchmarks, eyeball the fresh numbers, then either
``--update`` (copies fresh over the baselines) or commit the fresh files to
``benchmarks/baselines/`` by hand.  Baselines are per-file: a missing
baseline is reported and skipped, never failed, so adding a new benchmark
does not break the gate before its first baseline lands.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

LOWER_IS_BETTER = ("us_per_tick", "us_per_step", "us_per_cell", "wall_s")
# speedup_vs_subprocess compares two measurements from the SAME machine, so it
# is environment-relative — the most portable signal across runner classes
HIGHER_IS_BETTER = ("cells_per_sec", "speedup_vs_subprocess")
# environment measurements, not properties of the code under test (interpreter
# start-up, import cost, reference-machine extrapolations) — never gated
SKIP = ("extrapolated_wall_s_all_cells", "seconds_per_cell")
SKIP_PREFIXES = ("subprocess_baseline.", "sequential_inprocess_baseline.")

DEFAULT_NAMES = ("BENCH_grid.json", "BENCH_net.json")


def _walk(prefix: str, obj, out: dict):
    if isinstance(obj, dict):
        for k, v in obj.items():
            _walk(f"{prefix}.{k}" if prefix else k, v, out)
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)


def _metrics(path: str) -> dict[str, float]:
    with open(path) as f:
        flat: dict[str, float] = {}
        _walk("", json.load(f), flat)
    picked = {}
    for key, val in flat.items():
        leaf = key.rsplit(".", 1)[-1]
        if leaf in SKIP or key.startswith(SKIP_PREFIXES) or val <= 0:
            continue
        if leaf.endswith(LOWER_IS_BETTER) or leaf in HIGHER_IS_BETTER:
            picked[key] = val
    return picked


def compare(fresh_path: str, baseline_path: str, tol: float) -> list[str]:
    """Human-readable regression descriptions (empty = pass)."""
    fresh = _metrics(fresh_path)
    base = _metrics(baseline_path)
    problems = []
    for key in sorted(set(fresh) & set(base)):
        leaf = key.rsplit(".", 1)[-1]
        f, b = fresh[key], base[key]
        if leaf in HIGHER_IS_BETTER or key in HIGHER_IS_BETTER:
            if f < b / tol:
                problems.append(
                    f"{key}: {f:.4g} < baseline {b:.4g} / {tol:g} (higher is better)")
        elif f > b * tol:
            problems.append(
                f"{key}: {f:.4g} > baseline {b:.4g} * {tol:g} (lower is better)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", default=".")
    ap.add_argument("--baseline-dir", default=os.path.join("benchmarks", "baselines"))
    ap.add_argument("--names", default=",".join(DEFAULT_NAMES),
                    help="comma-separated BENCH_*.json file names to check")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_TOL", "1.5")),
                    help="allowed slowdown factor (default 1.5, env BENCH_TOL)")
    ap.add_argument("--update", action="store_true",
                    help="re-baseline: copy fresh files over the baselines")
    args = ap.parse_args(argv)

    failed = False
    checked = 0
    for name in args.names.split(","):
        fresh = os.path.join(args.fresh_dir, name)
        base = os.path.join(args.baseline_dir, name)
        if not os.path.exists(fresh):
            print(f"[skip] {name}: no fresh result at {fresh}")
            continue
        if args.update:
            os.makedirs(args.baseline_dir, exist_ok=True)
            shutil.copyfile(fresh, base)
            print(f"[rebaselined] {name} -> {base}")
            continue
        if not os.path.exists(base):
            print(f"[skip] {name}: no committed baseline at {base} "
                  f"(run with --update to create one)")
            continue
        problems = compare(fresh, base, args.tol)
        checked += 1
        if problems:
            failed = True
            print(f"[FAIL] {name} (tol {args.tol:g}x):")
            for p in problems:
                print(f"    {p}")
        else:
            print(f"[ok] {name} within {args.tol:g}x of baseline")
    if failed:
        print("benchmark regression detected — see docstring for how to "
              "re-baseline if this change is intentional")
        return 1
    if not args.update and checked == 0:
        print("nothing checked (no fresh result + baseline pairs found)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
