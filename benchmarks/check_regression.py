"""Benchmark regression gate: compare fresh ``BENCH_*.json`` files against
committed baselines and fail (exit 1) on wall-time regression.

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--fresh-dir .] [--baseline-dir benchmarks/baselines] \
        [--names BENCH_grid.json,BENCH_net.json] [--tol 1.5] \
        [--update [BENCH_comm.json ...]]

Metrics are discovered recursively by key name: keys ending in one of the
time suffixes (``us_per_tick``, ``us_per_step``, ``us_per_cell``,
``us_per_call``, ``wall_s``, ``seconds_per_cell``) are *lower-is-better*;
``cells_per_sec`` and anything containing ``speedup`` (same-machine ratios,
the most portable signal across runner classes) are *higher-is-better*.  A
metric regresses when it is worse than the committed baseline by more than
``--tol`` (default 1.5x, i.e. 50% slower; override per run or via the
``BENCH_TOL`` env var — CI runners are noisy, paper over a flaky gate by
bumping the tolerance, not by deleting the step).

Re-baselining (after an intentional perf change, or to adopt a new runner
class): run the benchmarks, eyeball the fresh numbers, then ``--update``
(bare: copies every fresh file over its baseline) or ``--update
BENCH_comm.json`` (only the named files), or commit the fresh files to
``benchmarks/baselines/`` by hand.  Baselines are per-file: a missing
baseline is a WARNING and a skip, never a failure, so a new ``BENCH_*.json``
can land (and be gated in CI) in the same PR that first baselines it.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

LOWER_IS_BETTER = ("us_per_tick", "us_per_step", "us_per_cell", "us_per_call",
                   "wall_s", "steady_state_s")
# "speedup" metrics compare two measurements from the SAME machine, so they
# are environment-relative — the most portable signal across runner classes
HIGHER_IS_BETTER = ("cells_per_sec", "ticks_per_sec")
# environment measurements, not properties of the code under test (interpreter
# start-up, import cost, reference-machine extrapolations, XLA compile time —
# compile cost rides the runner's cache state and core count) — never gated
SKIP = ("extrapolated_wall_s_all_cells", "seconds_per_cell", "compile_s")
SKIP_PREFIXES = ("subprocess_baseline.", "sequential_inprocess_baseline.")

DEFAULT_NAMES = ("BENCH_grid.json", "BENCH_net.json", "BENCH_comm.json",
                 "BENCH_kernels.json", "BENCH_breakdown.json", "BENCH_scale.json",
                 "BENCH_obs.json", "BENCH_trust.json", "BENCH_stream.json")


def _higher_is_better(leaf: str) -> bool:
    return leaf in HIGHER_IS_BETTER or "speedup" in leaf


def _walk(prefix: str, obj, out: dict):
    if isinstance(obj, dict):
        for k, v in obj.items():
            _walk(f"{prefix}.{k}" if prefix else k, v, out)
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)


def _metrics(path: str) -> dict[str, float]:
    with open(path) as f:
        flat: dict[str, float] = {}
        _walk("", json.load(f), flat)
    picked = {}
    for key, val in flat.items():
        leaf = key.rsplit(".", 1)[-1]
        if leaf in SKIP or key.startswith(SKIP_PREFIXES) or val <= 0:
            continue
        if leaf.endswith(LOWER_IS_BETTER) or _higher_is_better(leaf):
            picked[key] = val
    return picked


def compare(fresh_path: str, baseline_path: str, tol: float) -> list[str]:
    """Human-readable regression descriptions (empty = pass)."""
    fresh = _metrics(fresh_path)
    base = _metrics(baseline_path)
    problems = []
    # gate-able metrics present only in the fresh file (a benchmark grew a
    # new scenario/kernel) are not silently ungated forever: surface them so
    # the next --update re-baseline picks them up
    only_fresh = sorted(set(fresh) - set(base))
    if only_fresh:
        print(f"    [note] {len(only_fresh)} fresh metric(s) missing from the "
              f"baseline (not gated until re-baselined): {', '.join(only_fresh)}")
    for key in sorted(set(fresh) & set(base)):
        leaf = key.rsplit(".", 1)[-1]
        f, b = fresh[key], base[key]
        if _higher_is_better(leaf):
            if f < b / tol:
                problems.append(
                    f"{key}: {f:.4g} < baseline {b:.4g} / {tol:g} (higher is better)")
        elif f > b * tol:
            problems.append(
                f"{key}: {f:.4g} > baseline {b:.4g} * {tol:g} (lower is better)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", default=".")
    ap.add_argument("--baseline-dir", default=os.path.join("benchmarks", "baselines"))
    ap.add_argument("--names", default=",".join(DEFAULT_NAMES),
                    help="comma-separated BENCH_*.json file names to check")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_TOL", "1.5")),
                    help="allowed slowdown factor (default 1.5, env BENCH_TOL)")
    ap.add_argument("--update", nargs="*", default=None, metavar="BENCH_FILE",
                    help="re-baseline: copy fresh files over the baselines — "
                         "bare updates every --names file, or list specific "
                         "files (e.g. --update BENCH_comm.json)")
    args = ap.parse_args(argv)

    update_names = None
    if args.update is not None:
        update_names = set(args.update) if args.update else set(args.names.split(","))
        unknown = update_names - set(args.names.split(","))
        if unknown:
            # a typo must not exit 0 looking like a successful re-baseline
            for name in sorted(unknown):
                print(f"[error] --update {name}: not among --names "
                      f"({args.names}) — nothing re-baselined for it")
            return 1
    failed = False
    checked = 0
    for name in args.names.split(","):
        fresh = os.path.join(args.fresh_dir, name)
        base = os.path.join(args.baseline_dir, name)
        if not os.path.exists(fresh):
            print(f"[skip] {name}: no fresh result at {fresh}")
            continue
        if update_names is not None:
            if name in update_names:
                os.makedirs(args.baseline_dir, exist_ok=True)
                shutil.copyfile(fresh, base)
                print(f"[rebaselined] {name} -> {base}")
            continue
        if not os.path.exists(base):
            print(f"[warn-skip] {name}: no committed baseline at {base} — not "
                  f"gated this run (re-baseline with --update {name} and "
                  f"commit the file to make the gate bite)")
            continue
        problems = compare(fresh, base, args.tol)
        checked += 1
        if problems:
            failed = True
            print(f"[FAIL] {name} (tol {args.tol:g}x):")
            for p in problems:
                print(f"    {p}")
        else:
            print(f"[ok] {name} within {args.tol:g}x of baseline")
    if failed:
        print("benchmark regression detected — see docstring for how to "
              "re-baseline if this change is intentional")
        return 1
    if update_names is None and checked == 0:
        print("nothing checked (no fresh result + baseline pairs found)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
