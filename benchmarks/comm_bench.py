"""Compressed-exchange benchmark: bytes on the wire, accuracy at a bit
budget, and the fused dequant->screen kernel — writes ``BENCH_comm.json``.

Three measurements on the paper's MNIST-like linear task (d = 7850):

* **wire accounting** — exact bytes/edge/tick per codec (`Codec.wire_bits`),
  and the compression factor vs the float32 payload;
* **accuracy at a bit budget** — one codec x seed grid (`repro.sim`, ONE
  compiled program — the codec axis rides the same banked/grouped machinery
  as rules and attacks) under the random Byzantine attack: final loss and
  honest-node accuracy per codec, plus engine throughput vs an
  identity-only (uncompressed) engine of the same shape;
* **fused kernel** — `repro.kernels.dequant_screen` (dequantize inside the
  block) vs the staged decode-then-screen pipeline (dequant kernel
  materializing float32 [n, d], then the screening kernel), same execution
  mode for both sides, plus the jnp reference for context.

Acceptance (ISSUE 3): int8+top-k >= 4x fewer bytes/edge/tick with final loss
within 5% of uncompressed, and fused > staged.  The JSON records the
booleans; `tests/test_comm.py` pins the properties at test scale and CI
gates the timing metrics against ``benchmarks/baselines/BENCH_comm.json``.

    PYTHONPATH=src python -m benchmarks.comm_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_accuracy, get_data, make_grad_fn
from repro.comm import get_codec
from repro.core import replicate
from repro.data import partition_iid
from repro.data.partition import stack_node_batches
from repro.kernels import ops, ref
from repro.models import small
from repro.sim import ExperimentGrid, GridEngine
from repro.sim.engine import stack_batches
from repro.sim.grid import default_topology

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_comm.json")

CODECS = ("identity", "int8", "int4", "topk25_int8", "topk50_int8")
# the ISSUE's int8+top-k acceptance cell: k = d/2 keeps the loss inside the
# 5% band (sparser top-k trades accuracy for bits — the curve the figure
# shows) while enumerative index coding keeps the wire >= 4x smaller
ACCEPT_CODEC = "topk50_int8"


def codec_accuracy_grid(
    num_nodes: int = 12,
    ticks: int = 300,
    *,
    codecs=CODECS,
    rule: str = "trimmed_mean",
    attack: str = "random",
    num_byzantine: int = 2,
    seeds=(0,),
    seed: int = 0,
    loss_tail: int = 20,
    uncompressed_baseline: bool = True,
):
    """Run the codec axis as one compiled grid; returns (per-codec records,
    run meta).  Shared with `benchmarks.paper_figs.fig_comm_accuracy_vs_bits`
    so the figure and the gate run the same configuration through the same
    code path.  ``uncompressed_baseline=False`` skips the identity-only
    throughput engine (consumers that only want the accuracy-vs-bits curve)."""
    x, y, xt, yt = get_data()
    shards = partition_iid(x, y, num_nodes, seed=seed)
    batch_fn = stack_node_batches(shards, 32, seed=seed)
    topo = default_topology(num_nodes, (rule,), (num_byzantine,), seed=seed)
    grad_fn = make_grad_fn("linear")
    batches = stack_batches(
        lambda i: jax.tree_util.tree_map(jnp.asarray, batch_fn(i)), ticks)

    def init_fn(s):
        key = jax.random.PRNGKey(s)
        return replicate(small.init_linear(key), num_nodes, perturb=0.01, key=key)

    grid = ExperimentGrid(topo, (rule,), (attack,), (num_byzantine,), seeds,
                          codecs=tuple(codecs), lam=1.0, t0=30.0)
    engine = GridEngine(grid, grad_fn)
    t0 = time.perf_counter()
    state0 = engine.init(init_fn)
    state, metrics = engine.run(state0, batches)
    jax.block_until_ready(state.params)
    wall = time.perf_counter() - t0
    # re-run the cached program: steady-state scan cost without the compile
    t0 = time.perf_counter()
    jax.block_until_ready(engine.run(state0, batches)[0].params)
    wall_steady = time.perf_counter() - t0

    wall_base = base_cells = None
    if uncompressed_baseline:
        # identity-only engine of the same shape: the uncompressed throughput bar
        base_grid = ExperimentGrid(topo, (rule,), (attack,), (num_byzantine,), seeds,
                                   codecs=("identity",), lam=1.0, t0=30.0)
        base_engine = GridEngine(base_grid, grad_fn)
        t0 = time.perf_counter()
        bstate = base_engine.init(init_fn)
        bstate, _ = base_engine.run(bstate, batches)
        jax.block_until_ready(bstate.params)
        wall_base = time.perf_counter() - t0
        base_cells = base_engine.num_cells

    # the wire-accounting dimension is whatever the model actually flattens
    # to — derived, not pinned, so a model change can't desync the bits math
    from repro.core import stack_flatten

    one = jax.tree_util.tree_map(lambda leaf: leaf[0], state.params)
    d = int(stack_flatten(one)[0].shape[-1])
    xt, yt = jnp.asarray(xt), jnp.asarray(yt)
    per_codec: dict[str, dict] = {}
    for i, cell in enumerate(engine.cells):
        acc = eval_accuracy(
            "linear", jax.tree_util.tree_map(lambda leaf: leaf[i], state.params),
            ~engine.byz_masks[i], xt, yt)
        rec = per_codec.setdefault(cell.codec, {"losses": [], "accs": []})
        # mean over the trailing ticks: single-batch final losses are noisy
        # and the acceptance ratio should not ride one batch draw
        rec["losses"].append(float(np.asarray(metrics["loss"])[i, -loss_tail:].mean()))
        rec["accs"].append(float(acc))
    ident_bits = get_codec("identity").wire_bits(d)
    records = {}
    for name, rec in per_codec.items():
        bits = get_codec(name).wire_bits(d)
        records[name] = {
            "wire_bits_per_msg": bits,
            "bytes_per_edge_per_tick": bits / 8.0,
            "compression_x": ident_bits / bits,
            "final_loss": float(np.mean(rec["losses"])),
            "accuracy": float(np.mean(rec["accs"])),
        }
    ident_loss = records["identity"]["final_loss"]
    for rec in records.values():
        rec["loss_ratio_vs_identity"] = rec["final_loss"] / ident_loss
    meta = {
        "cells": engine.num_cells, "ticks": ticks, "num_nodes": num_nodes,
        "dim": d, "wall_s": wall, "trace_count": engine.trace_count,
        "compile_s": max(wall - wall_steady, 0.0),
        "steady_state_s": wall_steady,
        "cells_per_sec": engine.num_cells / wall,
        "ticks_per_sec": engine.num_cells * ticks / wall,
    }
    if uncompressed_baseline:
        meta["uncompressed"] = {
            "cells": base_cells, "wall_s": wall_base,
            "ticks_per_sec": base_cells * ticks / wall_base,
        }
        # throughput per cell relative to the uncompressed engine (the codec
        # axis pays encode/decode compute in exchange for the wire savings)
        meta["cell_throughput_vs_uncompressed"] = (
            (engine.num_cells / wall) / (base_cells / wall_base))
    return records, meta


def fused_kernel_bench(n: int = 25, d: int = 16384, b: int = 2, reps: int = 1):
    """Fused dequant->screen vs the staged decode-then-screen pipeline, both
    as Pallas kernels in the same execution mode (compiled on TPU, interpret
    on CPU), plus the jitted jnp reference for context."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    msg = get_codec("int8").encode(jax.random.PRNGKey(0), x)
    q, scale = msg.payload, msg.scale
    mask = jnp.ones((n,), bool)
    sv = jnp.asarray(rng.normal(size=(d,)), jnp.float32)

    def timeit(fn):
        fn().block_until_ready()
        t0 = time.perf_counter()
        for _ in range(reps):
            fn().block_until_ready()
        return (time.perf_counter() - t0) / reps * 1e6

    us_fused = timeit(lambda: ops.dequant_trimmed_mean(q, scale, mask, sv, b, block_d=512))
    us_staged = timeit(lambda: ops.trimmed_mean(
        ops.dequant(q, scale, block_d=512), mask, sv, b, block_d=512))
    us_ref = timeit(jax.jit(
        lambda: ref.dequant_trimmed_mean_ref(q, scale, mask, sv, b)).lower().compile())
    out_f = np.asarray(ops.dequant_trimmed_mean(q, scale, mask, sv, b, block_d=512))
    out_r = np.asarray(ref.dequant_trimmed_mean_ref(q, scale, mask, sv, b))
    agree = bool(np.allclose(out_f, out_r, rtol=1e-5, atol=1e-5))
    return {
        "n": n, "d": d, "b": b, "backend": jax.default_backend(),
        "fused_us": us_fused, "staged_us": us_staged,
        "ref_decode_screen_us": us_ref,
        "fused_speedup_vs_staged": us_staged / us_fused,
        "fused_matches_reference": agree,
        "float32_bytes_avoided": 4 * n * d,
    }


def comm_throughput(smoke: bool = False):
    """Returns CSV rows and writes BENCH_comm.json."""
    # the loss-parity claim needs the compressed cells past their delta
    # warm-up: 300 ticks full, 120 smoke (smoke checks plumbing, not parity)
    kw = dict(ticks=120, codecs=("identity", "int8", "topk50_int8")) if smoke else dict(ticks=300)
    records, meta = codec_accuracy_grid(**kw)
    kernel = fused_kernel_bench(d=4096 if smoke else 16384)

    accept_rec = records[ACCEPT_CODEC]
    acceptance = {
        "int8_topk_codec": ACCEPT_CODEC,
        "int8_topk_compression_x": accept_rec["compression_x"],
        "int8_topk_ge_4x_fewer_bytes": bool(accept_rec["compression_x"] >= 4.0),
        "int8_topk_loss_within_5pct": bool(accept_rec["loss_ratio_vs_identity"] <= 1.05),
        "fused_beats_staged": bool(kernel["fused_speedup_vs_staged"] > 1.0),
    }
    record = {"codecs": records, "grid": meta, "kernel": kernel,
              "acceptance": acceptance}
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)

    rows = []
    for name, rec in sorted(records.items()):
        rows.append((f"comm/codec/{name}", meta["wall_s"] / meta["cells"] * 1e6,
                     f"bytes_per_edge_tick={rec['bytes_per_edge_per_tick']:.0f};"
                     f"compression={rec['compression_x']:.2f}x;"
                     f"acc={rec['accuracy']:.4f};"
                     f"loss_ratio={rec['loss_ratio_vs_identity']:.4f}"))
    rows.append(("comm/grid", meta["wall_s"] * 1e6 / meta["cells"],
                 f"cells={meta['cells']};trace_count={meta['trace_count']};"
                 f"throughput_vs_uncompressed={meta['cell_throughput_vs_uncompressed']:.2f}x"))
    rows.append(("comm/kernel_fused", kernel["fused_us"],
                 f"staged_us={kernel['staged_us']:.0f};"
                 f"fused_speedup={kernel['fused_speedup_vs_staged']:.2f}x;"
                 f"matches_ref={kernel['fused_matches_reference']}"))
    if meta["trace_count"] != 1:
        raise RuntimeError(
            f"codec grid compiled {meta['trace_count']} times — the codec axis "
            f"broke the one-compile property (see repro.sim.engine)")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid + smaller kernel dims for quick runs")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for name, us, derived in comm_throughput(smoke=args.smoke):
        print(f"{name},{us:.1f},{derived}", flush=True)
    print(f"wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
