"""One benchmark per paper table/figure (Sec. V).

Each function returns CSV rows: (name, us_per_call, derived) where `derived`
is the figure's headline quantity (accuracy, comm cost, ...).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_accuracy, get_data, make_grad_fn, run_decentralized
from repro.core import ByrdieConfig, ByrdieTrainer, BrdsoConfig, BrdsoTrainer, erdos_renyi, replicate
from repro.core.screening import RULES
from repro.data import partition_iid
from repro.data.partition import stack_node_batches
from repro.models import small

M_DEFAULT = 20


def fig1_faultless_convex(num_nodes=M_DEFAULT, steps=120):
    """Fig. 1: DGD vs BRIDGE-T/M/K/B, linear classifier, no faults."""
    rows = []
    for rule, label in [("mean", "DGD"), ("trimmed_mean", "BRIDGE-T"),
                        ("median", "BRIDGE-M"), ("krum", "BRIDGE-K"),
                        ("bulyan", "BRIDGE-B")]:
        b = 0 if rule == "mean" else 2
        r = run_decentralized(model="linear", rule=rule, attack="none",
                              num_nodes=num_nodes, num_byzantine=b, steps=steps)
        rows.append((f"fig1/{label}", r["us_per_step"], f"acc={r['accuracy']:.4f}"))
    return rows


def fig2_byzantine_convex(num_nodes=M_DEFAULT, steps=120):
    """Fig. 2: DGD vs BRIDGE variants with 2 and 4 Byzantine nodes (random
    broadcast attack), linear classifier."""
    rows = []
    for b in (2, 4):
        for rule, label in [("mean", "DGD"), ("trimmed_mean", "BRIDGE-T"),
                            ("median", "BRIDGE-M"), ("krum", "BRIDGE-K"),
                            ("bulyan", "BRIDGE-B")]:
            r = run_decentralized(model="linear", rule=rule, attack="random",
                                  num_nodes=num_nodes, num_byzantine=b, steps=steps)
            rows.append((f"fig2/b{b}/{label}", r["us_per_step"], f"acc={r['accuracy']:.4f}"))
    return rows


def fig2_byzantine_convex_grid(num_nodes=M_DEFAULT, steps=120):
    """Fig. 2 through the batched grid engine (`repro.sim`): every rule x b
    cell of the figure inside ONE compiled program, consumed from the
    structured `GridResult` record instead of per-cell sequential runs."""
    import time as _time

    from repro.sim import ExperimentGrid, GridEngine, collect
    from repro.sim.engine import stack_batches
    from repro.sim.grid import default_topology

    from repro.core.screening import min_neighbors

    labels = [("mean", "DGD"), ("trimmed_mean", "BRIDGE-T"), ("median", "BRIDGE-M"),
              ("krum", "BRIDGE-K"), ("bulyan", "BRIDGE-B")]
    rules = tuple(r for r, _ in labels)
    x, y, xt, yt = get_data()
    shards = partition_iid(x, y, num_nodes, seed=0)
    batch_fn = stack_node_batches(shards, 32, seed=0)
    # keep only the b values every rule can tolerate at this network size
    # (the paper's b=4 bulyan cell needs the 20-node complete graph)
    bs = tuple(b for b in (2, 4)
               if max(min_neighbors(r, b) for r in rules) <= num_nodes - 1)
    # one shared topology dense enough for the strictest remaining cell
    topo = default_topology(num_nodes, rules, bs, seed=0)
    grid = ExperimentGrid(topo, rules, ("random",), bs, (0,), lam=1.0, t0=30.0)
    engine = GridEngine(grid, make_grad_fn("linear"))

    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        return replicate(small.init_linear(key), num_nodes, perturb=0.01, key=key)

    batches = jax.tree_util.tree_map(
        jnp.asarray,
        stack_batches(lambda i: tuple(jnp.asarray(a) for a in batch_fn(i)), steps))
    t0 = _time.perf_counter()
    state = engine.init(init_fn)
    state, metrics = engine.run(state, batches)
    jax.block_until_ready(state.params)
    wall = _time.perf_counter() - t0
    result = collect(engine.cells, metrics, meta={
        "wall_s": wall, "us_per_cell": wall / engine.num_cells * 1e6,
        "trace_count": engine.trace_count,
    })
    label_of = dict(labels)
    rows = []
    for i, rec in enumerate(result.cells):
        acc = eval_accuracy(
            "linear", jax.tree_util.tree_map(lambda leaf: leaf[i], state.params),
            ~engine.byz_masks[i], jnp.asarray(xt), jnp.asarray(yt))
        rows.append((f"fig2_grid/b{rec['b']}/{label_of[rec['rule']]}",
                     result.meta["us_per_cell"],
                     f"acc={acc:.4f};loss={rec['final_loss']:.4f}"))
    return rows


def fig3_byrdie_comm(num_nodes=M_DEFAULT, sweeps=2, bridge_steps=120):
    """Fig. 3: accuracy vs communication (scalars broadcast per node).
    BRIDGE-T broadcasts d scalars/iteration; ByRDiE needs d scalar rounds per
    sweep AND d gradient evaluations -> thousands-fold more communication
    rounds for the same model dimension."""
    x, y, xt, yt = get_data()
    d = 7850
    rows = []
    r = run_decentralized(model="linear", rule="trimmed_mean", attack="random",
                          num_nodes=num_nodes, num_byzantine=2, steps=bridge_steps)
    bridge_scalars = bridge_steps * d
    rows.append(("fig3/BRIDGE-T", r["us_per_step"],
                 f"acc={r['accuracy']:.4f};broadcast_rounds={bridge_steps};"
                 f"scalars_per_node={bridge_scalars}"))

    shards = partition_iid(x, y, num_nodes, seed=0)
    batch_fn = stack_node_batches(shards, 32, seed=0)
    topo = erdos_renyi(num_nodes, 0.5, 2, seed=0)
    cfg = ByrdieConfig(topology=topo, num_byzantine=2, attack="random",
                       block=512, t0=30.0)
    tr = ByrdieTrainer(cfg, make_grad_fn("linear"))
    params = replicate(small.init_linear(jax.random.PRNGKey(0)), num_nodes,
                       perturb=0.01, key=jax.random.PRNGKey(0))
    st = tr.init(params)
    t0 = time.perf_counter()
    for i in range(sweeps):
        bx, by = batch_fn(i)
        st, m = tr.sweep(st, (jnp.asarray(bx), jnp.asarray(by)))
    wall = (time.perf_counter() - t0) / sweeps * 1e6
    acc = eval_accuracy("linear", st.params, ~tr.byz_mask, jnp.asarray(xt), jnp.asarray(yt))
    rows.append(("fig3/ByRDiE", wall,
                 f"acc={acc:.4f};broadcast_rounds={sweeps*d};"
                 f"scalars_per_node={int(m['scalars_sent'])};"
                 f"note=1 sweep == d={d} sequential scalar rounds + {d} grad"
                 f" evals (block=512 approximates the grad recomputation)"))
    return rows


def fig45_nonconvex(num_nodes=10, steps=80):
    """Figs. 4-5: CNN (nonconvex).  Faultless + b in {2} Byzantine."""
    rows = []
    for attack, b, label in [("none", 0, "faultless/DGD"), ("none", 2, "faultless/BRIDGE-T"),
                             ("random", 2, "b2/DGD"), ("random", 2, "b2/BRIDGE-T"),
                             ("random", 2, "b2/BRIDGE-M")]:
        rule = "mean" if "DGD" in label else ("median" if label.endswith("-M") else "trimmed_mean")
        # num_byzantine doubles as the attacked-node count (attack != none)
        # and the screening trim parameter; DGD ignores the latter.
        nbyz = b if attack != "none" else (0 if rule == "mean" else max(b, 1))
        r = run_decentralized(model="cnn", rule=rule, attack=attack,
                              num_nodes=num_nodes, num_byzantine=nbyz,
                              steps=steps, t0=20.0, lam=1.0)
        rows.append((f"fig45/{label}", r["us_per_step"], f"acc={r['accuracy']:.4f}"))
    return rows


def fig67_noniid(num_nodes=M_DEFAULT, steps=150):
    """Figs. 6-7: BRIDGE-T vs BRDSO under extreme/moderate non-iid data."""
    rows = []
    x, y, xt, yt = get_data()
    for part in ("extreme", "moderate"):
        for b in (0, 2, 4):
            r = run_decentralized(model="linear", rule="trimmed_mean",
                                  attack="random" if b else "none",
                                  num_nodes=num_nodes, num_byzantine=b,
                                  partition=part, steps=steps)
            rows.append((f"fig67/{part}/b{b}/BRIDGE-T", r["us_per_step"],
                         f"acc={r['accuracy']:.4f}"))
            # BRDSO baseline
            from repro.data import partition_extreme_noniid, partition_moderate_noniid
            pfn = partition_extreme_noniid if part == "extreme" else partition_moderate_noniid
            shards = pfn(x, y, num_nodes, seed=0)
            batch_fn = stack_node_batches(shards, 32, seed=0)
            topo = erdos_renyi(num_nodes, 0.5, max(b, 1), seed=0)
            cfg = BrdsoConfig(topology=topo, num_byzantine=b,
                              attack="random" if b else "none", lam0=0.02, t0=30.0)
            tr = BrdsoTrainer(cfg, make_grad_fn("linear"))
            params = replicate(small.init_linear(jax.random.PRNGKey(0)), num_nodes,
                               perturb=0.01, key=jax.random.PRNGKey(0))
            st = tr.init(params)
            t0 = time.perf_counter()
            for i in range(steps):
                bx, by = batch_fn(i)
                st, _ = tr.step(st, (jnp.asarray(bx), jnp.asarray(by)))
            wall = (time.perf_counter() - t0) / steps * 1e6
            acc = eval_accuracy("linear", st.params, ~tr.byz_mask, jnp.asarray(xt), jnp.asarray(yt))
            rows.append((f"fig67/{part}/b{b}/BRDSO", wall, f"acc={acc:.4f}"))
    return rows


def fig_comm_accuracy_vs_bits(num_nodes=12, ticks=300):
    """Accuracy vs bits-on-wire: the codec axis (identity -> int8 -> int4 ->
    top-k+int8) as one compiled grid, each point a (bytes/edge/tick,
    accuracy, loss-vs-uncompressed) triple — the compressed-exchange
    trade-off curve `BENCH_comm.json` gates.  Runs the same configuration
    through the same `benchmarks.comm_bench` code path as the gate (minus
    the gate-only uncompressed-throughput engine)."""
    from benchmarks.comm_bench import codec_accuracy_grid

    records, meta = codec_accuracy_grid(num_nodes=num_nodes, ticks=ticks,
                                        uncompressed_baseline=False)
    rows = []
    for name, rec in sorted(records.items(), key=lambda kv: -kv[1]["wire_bits_per_msg"]):
        rows.append((f"fig_comm/{name}", meta["wall_s"] / meta["cells"] * 1e6,
                     f"bytes_per_edge_tick={rec['bytes_per_edge_per_tick']:.0f};"
                     f"acc={rec['accuracy']:.4f};"
                     f"loss_ratio={rec['loss_ratio_vs_identity']:.4f}"))
    return rows


def fig_breakdown(num_nodes=10, ticks=60, b_max=3):
    """Breakdown curves (repro.adversary): honest loss / test accuracy vs the
    actual Byzantine count b, per screening rule, under static AND adaptive
    adversaries — with the monotone-certified breakdown point b* each pair
    earns.  The companion figure to fig_comm: where fig_comm trades accuracy
    against bits, this trades it against adversarial budget.  Runs the same
    `benchmarks.breakdown_bench` certification the CI gate consumes."""
    from benchmarks.breakdown_bench import run_certification
    from repro.adversary.breakdown import breakdown_curve

    result = run_certification(num_nodes=num_nodes, ticks=ticks, b_max=b_max)
    us = result["meta"]["wall_s"] / max(result["meta"]["cells_run"], 1) * 1e6
    rows = []
    for rule, adv, b, loss, score in breakdown_curve(result):
        bstar = result["rules"][rule]["adversaries"][adv]["bstar"]
        acc = "" if score is None else f"acc={score:.4f};"
        rows.append((f"fig_breakdown/{rule}/{adv}/b{b}", us,
                     f"loss={loss:.4f};{acc}bstar={bstar}"))
    return rows


def table2_screening_cost(d=100_000, n=25, b=2, reps=5):
    """Table II: per-call screening cost — BRIDGE-T/M are O(nd), K/B O(n^2 d)."""
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    mask = jnp.ones((n,), bool)
    self_v = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    rows = []
    for rule in ["trimmed_mean", "median", "krum", "bulyan", "mean"]:
        fn = jax.jit(lambda v, m, s: RULES[rule](v, m, s, b))
        fn(vals, mask, self_v).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(reps):
            fn(vals, mask, self_v).block_until_ready()
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"table2/{rule}", us, f"n={n};d={d};b={b}"))
    return rows
