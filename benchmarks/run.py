"""Benchmark harness — one function per paper table/figure (Sec. V) plus the
screening-kernel sweep.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,table2] [--full] \
        [--scenario sync|async_lossy]

``--full`` uses the paper's 50-node network (slower); default is 20 nodes.
``--scenario async_lossy`` runs the `repro.net` network-condition axis (drop,
latency, bandwidth caps, churn, partition-and-heal) and writes
``BENCH_net.json`` alongside the CSV.  ``--only grid`` times the batched
grid engine against the subprocess sweep baseline and writes
``BENCH_grid.json`` (also runnable directly: ``python -m
benchmarks.grid_bench``); ``--only fig2_grid`` reproduces Fig. 2 through the
grid engine in one compiled program.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated benchmark keys")
    ap.add_argument("--full", action="store_true", help="50-node networks (paper scale)")
    ap.add_argument("--scenario", default="sync", choices=["sync", "async_lossy"],
                    help="network model: sync broadcast or repro.net scenarios")
    args = ap.parse_args()

    from benchmarks import comm_bench, grid_bench, kernels_bench, net_bench, paper_figs

    m = 50 if args.full else 20
    benches = {
        "fig1": lambda: paper_figs.fig1_faultless_convex(num_nodes=m),
        "fig2": lambda: paper_figs.fig2_byzantine_convex(num_nodes=m),
        "fig2_grid": lambda: paper_figs.fig2_byzantine_convex_grid(num_nodes=m),
        "fig3": lambda: paper_figs.fig3_byrdie_comm(num_nodes=m),
        "fig45": lambda: paper_figs.fig45_nonconvex(num_nodes=min(m, 10)),
        "fig67": lambda: paper_figs.fig67_noniid(num_nodes=m),
        "table2": paper_figs.table2_screening_cost,
        "fig_comm": paper_figs.fig_comm_accuracy_vs_bits,
        "fig_breakdown": paper_figs.fig_breakdown,
        "kernels": kernels_bench.kernel_throughput,
        "net": lambda: net_bench.async_lossy_scenarios(num_nodes=m),
        "grid": grid_bench.grid_throughput,
        "comm": comm_bench.comm_throughput,
    }
    if args.scenario == "async_lossy":
        only = {"net"}
    else:
        # net/grid/comm/kernels have their own CI jobs + JSON records (and
        # overwrite the repo-root BENCH_*.json); opt in via --only
        only = set(benches) - {"net", "grid", "comm", "fig_comm",
                               "fig_breakdown", "kernels"}
    if args.only:
        only = set(args.only.split(","))
    print("name,us_per_call,derived")
    for key, fn in benches.items():
        if key not in only:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # keep the harness running
            print(f"{key}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}", flush=True)
        print(f"# {key} took {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
