"""Chunk-streaming benchmark: the model zoo under attack through
``repro.stream`` — writes ``BENCH_stream.json``.

Three measurements (ISSUE 8 acceptance):

* **peak-memory bound** — a multi-million-parameter qwen3-family transformer
  trains end-to-end under a Byzantine attack through `StreamBridgeTrainer`.
  The jitted step's optimized HLO is scanned with
  `repro.launch.hlo_analysis.largest_tensor_bytes` to *prove* the streaming
  path never materializes the flat ``[M, d]`` f32 matrix: the largest live
  tensor must stay strictly below ``M * d * 4`` bytes (the flat path's
  smallest full-parameter tensor — `stack_flatten`'s output, before the
  ``[M, M, d]``/``[M, K, d]`` exchange views it feeds).
* **throughput** — steady-state seconds per streaming step (compile
  excluded), gated against the committed baseline by
  ``benchmarks.check_regression``.
* **loss parity** — at small ``d`` a tiny transformer runs flat AND
  streaming under a deterministic attack: trajectories must be bitwise
  identical (so loss parity is exact, not approximate).

CI runs ``--smoke`` (the ~6.6M-param ``--small`` config from
``examples/train_llm.py``, few steps), so the committed artifact AND baseline
are smoke-sized; the full run (no flag) uses the ~100M-param config and
overwrites ``BENCH_stream.json`` with timings NOT comparable against the
smoke baseline.

    PYTHONPATH=src python -m benchmarks.stream_bench [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import replicate
from repro.core.bridge import BridgeConfig, BridgeTrainer, stack_flatten
from repro.core.graph import erdos_renyi
from repro.data.tokens import TokenPipeline
from repro.launch import hlo_analysis
from repro.models import api as model_api
from repro.stream import StreamBridgeTrainer

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_stream.json")

RULE = "trimmed_mean"
ATTACK = "sign_flip"  # deterministic: streaming == flat bitwise at any chunk
M, B = 4, 1
CHUNK = 1 << 16


def _model(smoke: bool):
    base = get_config("qwen3-4b")
    if smoke:  # the train_llm.py --small config (~6.6M params)
        cfg = base.reduced(num_layers=4, d_model=256, num_heads=4,
                           num_kv_heads=2, d_ff=512, vocab_size=8192,
                           head_dim=64)
        seq, batch = 64, 1
    else:  # the ~100M-param real config
        cfg = dataclasses.replace(
            base, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            d_ff=2048, vocab_size=32768, head_dim=64, kv_chunk=256, q_chunk=128)
        seq, batch = 256, 2
    return cfg, seq, batch


def _tiny_model():
    """Small enough that the flat [M, d] path is cheap — the parity oracle."""
    cfg = get_config("qwen3-4b").reduced(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=1, d_ff=128,
        vocab_size=512, head_dim=32)
    return cfg, 32, 1


def _build(cfg, seq, batch, *, flat: bool, chunk: int):
    api = model_api.build(cfg)
    topo = erdos_renyi(M, 0.9, B, seed=1)
    bcfg = BridgeConfig(topology=topo, rule=RULE, num_byzantine=B,
                        attack=ATTACK, lr=0.02,
                        screen_chunk=(1 << 30) if flat else chunk)
    tr = (BridgeTrainer(bcfg, api.grad_fn()) if flat
          else StreamBridgeTrainer(bcfg, api.grad_fn()))
    key = jax.random.PRNGKey(0)
    params = replicate(api.init_params(key, cfg), M, perturb=0.005, key=key)
    state = tr.init(params, seed=0)
    pipe = TokenPipeline(cfg.vocab_size, seq, batch, M, seed=0)
    batch_fn = lambda i: jax.tree_util.tree_map(jnp.asarray, pipe.batch(i))
    return tr, state, batch_fn


def _time_steps(tr, state, batch_fn, steps: int):
    """Steady-state s/step (compile excluded via a warm-up step on the same
    shapes), the compile cost, and the per-step losses."""
    t0 = time.perf_counter()
    warm, _ = tr.step(state, batch_fn(0))
    jax.block_until_ready(warm.params)
    wall_first = time.perf_counter() - t0
    losses = []
    t0 = time.perf_counter()
    st = state
    for i in range(steps):
        st, m = tr.step(st, batch_fn(i))
        losses.append(m["loss"])
    jax.block_until_ready(st.params)
    wall = time.perf_counter() - t0
    per_step = wall / steps
    return per_step, max(wall_first - per_step, 0.0), np.asarray(
        jax.device_get(losses), np.float64), st


def hlo_stream_bound(tr, state, batch_fn) -> dict:
    """Lower the jitted streaming step, scan the optimized HLO: the largest
    tensor must be strictly below the flat path's [M, d] f32 matrix."""
    d = sum(p.size for p in tr.spec.leaves)
    lowered = jax.jit(tr._raw_step).lower(tr._cell, state, batch_fn(0))
    text = lowered.compile().as_text()
    largest = hlo_analysis.largest_tensor_bytes(text)
    flat_bytes = M * d * 4
    k = M if tr.neighbors is None else tr.neighbors.k
    return {
        "num_nodes": M, "dim": int(d), "chunk": int(tr.spec.chunk),
        "largest_tensor_bytes": int(largest),
        "flat_Md_bytes": int(flat_bytes),
        "MKchunk_bytes": int(M * k * tr.spec.max_block * 4),
        "largest_over_flat": largest / flat_bytes,
        "below_flat_matrix": bool(largest < flat_bytes),
    }


def _parity() -> dict:
    """Flat vs streaming on the tiny transformer: bitwise trajectories."""
    cfg, seq, batch = _tiny_model()
    steps = 3
    tr_f, st_f, bf = _build(cfg, seq, batch, flat=True, chunk=CHUNK)
    tr_s, st_s, _ = _build(cfg, seq, batch, flat=False, chunk=8192)
    loss_f = loss_s = None
    for i in range(steps):
        st_f, mf = tr_f.step(st_f, bf(i))
        st_s, ms = tr_s.step(st_s, bf(i))
        loss_f, loss_s = float(mf["loss"]), float(ms["loss"])
    identical = bool(jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all(a == b)), st_f.params, st_s.params)))
    d = int(stack_flatten(st_f.params)[0].shape[-1])
    return {
        "dim": d, "steps": steps, "stream_chunk": 8192,
        "num_blocks": int(tr_s.spec.num_blocks),
        "flat_loss": loss_f, "stream_loss": loss_s,
        "loss_abs_diff": abs(loss_f - loss_s),
        "bit_identical": identical,
    }


def run(smoke: bool = False) -> dict:
    steps = 3 if smoke else 5
    cfg, seq, batch = _model(smoke)
    n_params = model_api.param_count(cfg)

    parity = _parity()

    tr, state, batch_fn = _build(cfg, seq, batch, flat=False, chunk=CHUNK)
    hlo = hlo_stream_bound(tr, state, batch_fn)
    s_per_step, compile_s, losses, _ = _time_steps(tr, state, batch_fn, steps)

    record = {
        "backend": jax.default_backend(),
        "config": {
            "model_params": int(n_params), "num_nodes": M, "b": B,
            "rule": RULE, "attack": ATTACK, "chunk": CHUNK,
            "seq": seq, "batch": batch, "steps": steps, "smoke": smoke,
        },
        "stream": {
            "us_per_step": s_per_step * 1e6,
            "compile_s": compile_s,
            "first_loss": float(losses[0]), "last_loss": float(losses[-1]),
            "loss_finite": bool(np.isfinite(losses).all()),
            "hlo": hlo,
        },
        "parity": parity,
        "acceptance": {
            "trains_under_attack": bool(np.isfinite(losses).all()),
            "peak_below_flat_matrix": hlo["below_flat_matrix"],
            "flat_stream_bit_identical": parity["bit_identical"],
        },
    }
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (the ~6.6M-param config, fewer steps)")
    args = ap.parse_args(argv)
    record = run(smoke=args.smoke)
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    st = record["stream"]
    print(f"{record['config']['model_params']/1e6:.1f}M params x {M} nodes "
          f"under {ATTACK}: {st['us_per_step']/1e6:.2f} s/step, "
          f"loss {st['first_loss']:.4f} -> {st['last_loss']:.4f}")
    print(f"largest HLO tensor {st['hlo']['largest_tensor_bytes']:,} B = "
          f"{st['hlo']['largest_over_flat']:.3f} of the flat [M,d] matrix "
          f"({st['hlo']['flat_Md_bytes']:,} B)")
    print(f"parity at d={record['parity']['dim']} "
          f"({record['parity']['num_blocks']} blocks): "
          f"bit_identical={record['parity']['bit_identical']}")
    print("acceptance:", record["acceptance"])
    print(f"wrote {BENCH_JSON}")
    if not all(record["acceptance"].values()):
        raise SystemExit(f"stream acceptance failed: {record['acceptance']}")


if __name__ == "__main__":
    main()
